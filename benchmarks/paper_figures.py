"""Benchmarks mirroring the paper's figures (one function per figure).

Each returns a list of CSV rows (name, value, derived-info).  FL-based
figures run the simulator in a CPU-budget profile (same structure as
Table 3, smaller local datasets); REPRO_BENCH_ROUNDS / REPRO_BENCH_FULL
control the cost.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.core.overhead import (GBoardParams, crossing_interval_s,
                                 fig2_curves, fig9_curves)
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "5"))
FULL = os.environ.get("REPRO_BENCH_FULL", "") == "1"


def _fl_cfg(scheme: str, classes: int = 9, seed: int = 0) -> FLSimConfig:
    if FULL:
        part = PartitionConfig(classes_per_client=classes)
        return FLSimConfig(scheme=scheme, partition=part, seed=seed,
                           local_epochs=2)
    part = PartitionConfig(big_quantity=200, small_quantity=45,
                           classes_per_client=classes)
    # fewer classes/client concentrates per-class demand (no-dup rule):
    # grow the source pool accordingly
    pool = 520 + (9 - classes) * 60
    return FLSimConfig(scheme=scheme, partition=part, seed=seed,
                       local_epochs=1, samples_per_class=pool,
                       probe_samples=128)


def _run_fl(cfg: FLSimConfig, rounds: int = ROUNDS) -> Dict:
    sim = FLSimulation(cfg)
    t0 = time.time()
    hist = sim.run(rounds)
    return {
        "final_acc": hist[-1]["accuracy"],
        "best_acc": max(h["accuracy"] for h in hist),
        "avg_selected": float(np.mean([h["n_selected"] for h in hist])),
        "avg_aggregated": float(np.mean([h["n_aggregated"] for h in hist])),
        "state_bytes_round": hist[0]["state_bytes"],
        "state_time_s_round": hist[0]["state_time_s"],
        "wall_s": time.time() - t0,
        "history": hist,
    }


# --------------------------------------------------------------------------

def bench_fig2_overhead() -> List[str]:
    """Fig. 2: state-maintenance bytes vs interval, GBoard parameters."""
    rows = []
    iv = np.array([1.0, 5.0, 15.0, 52.0, 100.0])
    c = fig2_curves(iv)
    p = GBoardParams()
    t0 = time.time()
    for i, t in enumerate(iv):
        rows.append(f"fig2_cfl_bytes@tau={t:g},{c['cfl_bytes'][i]:.3e},"
                    f"upload={c['upload_bytes'][i]:.3e}")
    x_cfl = crossing_interval_s(p.n_participants, p.state_bytes_cfl,
                                p.round_period_s, p.clients_per_round,
                                p.model_bytes)
    x_fuz = crossing_interval_s(p.n_participants, p.state_bytes_ccs_fuzzy,
                                p.round_period_s, p.clients_per_round,
                                p.model_bytes)
    us = (time.time() - t0) * 1e6
    rows.append(f"fig2_crossing_cfl_s,{x_cfl:.1f},paper=52")
    rows.append(f"fig2_crossing_ccsfuzzy_s,{x_fuz:.1f},paper=15")
    rows.append(f"fig2_us_per_call,{us:.1f},analytic")
    return rows


def bench_fig6_accuracy() -> List[str]:
    """Fig. 6: accuracy of DCS vs CCS-fuzzy vs random (9 classes/vehicle)."""
    rows = []
    results = {}
    for scheme in ("dcs", "ccs-fuzzy", "random"):
        r = _run_fl(_fl_cfg(scheme))
        results[scheme] = r
        rows.append(f"fig6_{scheme}_final_acc,{r['final_acc']:.4f},"
                    f"best={r['best_acc']:.4f};avg_sel={r['avg_selected']:.2f};"
                    f"wall_s={r['wall_s']:.0f}")
    # paper claims: DCS ~ CCS-fuzzy, both >= random (after enough rounds);
    # DCS average selected ~ 5
    ok = results["dcs"]["best_acc"] >= results["random"]["best_acc"] - 0.05
    rows.append(f"fig6_dcs_ge_random,{int(ok)},claim=DCS beats random")
    return rows


def bench_fig7_distribution() -> List[str]:
    """Fig. 7: vehicle distribution (uniform vs extreme) influence on DCS."""
    rows = []
    for dist in ("uniform", "extreme"):
        cfg = _fl_cfg("dcs", seed=1)
        cfg.mobility = MobilityConfig(distribution=dist, seed=1)
        r = _run_fl(cfg)
        rows.append(f"fig7_dcs_{dist}_final_acc,{r['final_acc']:.4f},"
                    f"avg_sel={r['avg_selected']:.2f};"
                    f"wall_s={r['wall_s']:.0f}")
    return rows


def bench_fig8_noniid() -> List[str]:
    """Fig. 8: non-iid level (9/6/2 classes per vehicle), DCS vs random."""
    rows = []
    for classes in (9, 6, 2):
        for scheme in ("dcs", "random"):
            r = _run_fl(_fl_cfg(scheme, classes=classes, seed=2))
            rows.append(
                f"fig8_{scheme}_{classes}cls_final_acc,{r['final_acc']:.4f},"
                f"best={r['best_acc']:.4f};wall_s={r['wall_s']:.0f}")
    return rows


def bench_fig9_accumulated_time() -> List[str]:
    """Fig. 9: accumulated communication time vs sending interval, Tokyo."""
    rows = []
    iv = np.array([0.5, 1.0, 2.0, 5.0, 10.0, 20.0])
    c = fig9_curves(iv)
    for i, t in enumerate(iv):
        rows.append(
            f"fig9@tau={t:g},dcs={c['dcs'][i]:.3e},"
            f"ccs={c['ccs'][i]:.3e};ccs_fuzzy={c['ccs-fuzzy'][i]:.3e};"
            f"model_only={c['model-only'][i]:.3e}")
    ordering = bool((c["dcs"] < c["ccs"]).all()
                    and (c["dcs"] < c["ccs-fuzzy"]).all())
    rows.append(f"fig9_dcs_lowest,{int(ordering)},claim=DCS lowest time")
    return rows
