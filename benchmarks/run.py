"""Benchmark harness — one function per paper table/figure + the
framework-level benches.  Prints ``name,value,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run              # all
  PYTHONPATH=src python -m benchmarks.run fig2 fig9    # subset
Env:
  REPRO_BENCH_ROUNDS=N   FL rounds per curve (default 5)
  REPRO_BENCH_FULL=1     Table-3-scale FL profile (slow on CPU)
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.engine_throughput import (bench_engine_throughput,
                                          bench_round_overlap,
                                          bench_trainer_unroll)
from benchmarks.kernels_bench import (bench_fuzzy_eval, bench_neighbor_elect,
                                      bench_probe_fuzzy, bench_scan_unroll,
                                      bench_wkv6)
from benchmarks.prefix_fusion import bench_prefix_fusion
from benchmarks.paper_figures import (bench_fig2_overhead,
                                      bench_fig6_accuracy,
                                      bench_fig7_distribution,
                                      bench_fig8_noniid,
                                      bench_fig9_accumulated_time)
from benchmarks.roofline import bench_roofline_table
from benchmarks.staleness import bench_staleness, bench_staleness_lambda
from benchmarks.selection_collectives import (bench_prefix_sharding,
                                              bench_selection_collectives,
                                              bench_windowed_scaling)

BENCHES = {
    "engine_throughput": bench_engine_throughput,
    "fig2": bench_fig2_overhead,
    "fig6": bench_fig6_accuracy,
    "fig7": bench_fig7_distribution,
    "fig8": bench_fig8_noniid,
    "fig9": bench_fig9_accumulated_time,
    "engine_overlap": bench_round_overlap,
    "kernels_fuzzy": bench_fuzzy_eval,
    "kernels_elect": bench_neighbor_elect,
    "kernels_probe_fuzzy": bench_probe_fuzzy,
    "kernels_scan_unroll": bench_scan_unroll,
    "kernels_wkv6": bench_wkv6,
    "prefix_fusion": bench_prefix_fusion,
    "prefix_sharding": bench_prefix_sharding,
    "selection_collectives": bench_selection_collectives,
    "windowed_scaling": bench_windowed_scaling,
    "staleness": bench_staleness,
    "staleness_lambda": bench_staleness_lambda,
    "roofline": bench_roofline_table,
    "trainer_unroll": bench_trainer_unroll,
}


def main() -> int:
    names = sys.argv[1:] or list(BENCHES)
    failed = []
    print("name,value,derived")
    for name in names:
        fn = BENCHES.get(name)
        if fn is None:
            print(f"{name},NaN,unknown bench (known: {' '.join(BENCHES)})")
            failed.append(name)
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
            print(f"{name}_wall_s,{time.time()-t0:.1f},bench total",
                  flush=True)
        except Exception as e:                       # noqa: BLE001
            traceback.print_exc()
            print(f"{name}_error,1,{type(e).__name__}: {e}", flush=True)
            failed.append(name)
    # a raising (or unknown) bench must gate CI, not just print
    return 1 if failed else 0


if __name__ == '__main__':
    raise SystemExit(main())
