"""Render the EXPERIMENTS.md roofline/dry-run tables from results/*.jsonl.

  PYTHONPATH=src python -m benchmarks.render_experiments
prints markdown tables for the §Dry-run and §Roofline sections.
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path):
    rows = {}
    full = os.path.join(RESULTS, path)
    if not os.path.exists(full):
        return rows
    with open(full) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"])] = r
    return rows


def fmt(x, nd=2):
    return f"{x:.{nd}f}"


def roofline_table(rows, title):
    out = [f"\n### {title}\n"]
    out.append("| arch | shape | dominant | compute s | memory s | "
               "collective s | useful | peak GiB | fits 16G |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for shape in SHAPE_ORDER:
        for (a, s), r in sorted(rows.items()):
            if s != shape:
                continue
            if not r.get("ok"):
                out.append(f"| {a} | {s} | **FAILED** | | | | | | |")
                continue
            rl = r["roofline"]
            m = r["memory"]
            out.append(
                f"| {a} | {s} | {rl['dominant']} | {fmt(rl['compute_s'])} "
                f"| {fmt(rl['memory_s'])} | {fmt(rl['collective_s'])} "
                f"| {fmt(r['useful_compute_ratio'])} "
                f"| {fmt(m['peak_bytes']/2**30)} "
                f"| {'yes' if m['fits_16g'] else 'NO'} |")
    return "\n".join(out)


def delta_table(base, opt):
    out = ["\n### Baseline -> optimized deltas (single-pod)\n"]
    out.append("| arch | shape | dom (b->o) | mem s (b->o) | coll s (b->o) "
               "| peak GiB (b->o) |")
    out.append("|---|---|---|---|---|---|")
    for key in sorted(base):
        b, o = base.get(key), opt.get(key)
        if not (b and o and b.get("ok") and o.get("ok")):
            continue
        rb, ro = b["roofline"], o["roofline"]
        pb = b["memory"]["peak_bytes"] / 2**30
        po = o["memory"]["peak_bytes"] / 2**30
        if (abs(rb["memory_s"] - ro["memory_s"]) / max(rb["memory_s"], 1e-9)
                < 0.03 and abs(pb - po) < 0.3
                and abs(rb["collective_s"] - ro["collective_s"])
                / max(rb["collective_s"], 1e-9) < 0.05):
            continue                       # unchanged rows omitted
        out.append(
            f"| {key[0]} | {key[1]} | {rb['dominant']}->{ro['dominant']} "
            f"| {fmt(rb['memory_s'])}->{fmt(ro['memory_s'])} "
            f"| {fmt(rb['collective_s'])}->{fmt(ro['collective_s'])} "
            f"| {fmt(pb)}->{fmt(po)} |")
    return "\n".join(out)


def main():
    base = load("dryrun_paper_baseline.jsonl")
    opt = load("dryrun_optimized.jsonl")
    mp = load("dryrun_optimized_multipod.jsonl")
    print(roofline_table(base, "Paper-faithful baseline (16x16 single pod)"))
    print(roofline_table(opt, "Optimized (16x16 single pod)"))
    print(delta_table(base, opt))
    print(roofline_table(mp, "Optimized (2x16x16 multi-pod)"))
    ok = sum(1 for r in mp.values() if r.get("ok"))
    print(f"\nmulti-pod: {ok}/{len(mp)} combos compile")


if __name__ == "__main__":
    main()
