"""Kernel micro-benchmarks at paper scale (us_per_call, jnp fast path).

The Pallas kernels target TPU; on this CPU container they execute in
interpret mode (orders of magnitude slower than compiled), so wall-time
here benchmarks the jnp dispatch path and records interpret-mode cost for
reference only on tiny sizes.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.selection import dcs_select
from repro.kernels import ops as kops


def _time(fn, *args, repeats=3, **kw) -> float:
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_fuzzy_eval() -> List[str]:
    rows = []
    ev = FuzzyEvaluator()
    for p in (30, 10_000, 1_000_000):      # road, city, Tokyo-scale/3
        x = jax.random.uniform(jax.random.PRNGKey(0), (p, 4))
        fn = jax.jit(ev.evaluate)
        us = _time(fn, x)
        rows.append(f"fuzzy_eval_jnp_P={p},{us:.1f},us_per_call;"
                    f"{p/us:.1f} vehicles/us")
    return rows


def bench_neighbor_elect() -> List[str]:
    rows = []
    for n in (30, 1000, 10_000):
        pos = jax.random.uniform(jax.random.PRNGKey(1), (n,)) * 1000.0 * n / 30
        evl = jax.random.uniform(jax.random.PRNGKey(2), (n,)) * 100.0
        fn = jax.jit(lambda p, e: dcs_select(p, e, comm_range=200.0,
                                             top_m=2, e_tau=30.0))
        us = _time(fn, pos, evl)
        rows.append(f"neighbor_elect_jnp_N={n},{us:.1f},us_per_call")
    return rows


def bench_wkv6() -> List[str]:
    rows = []
    b, h, n = 1, 4, 64
    for t in (256, 1024):
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.5 + 0.5
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        s0 = jnp.zeros((b, h, n, n))
        fn = jax.jit(lambda *a: kops.wkv6(*a)[0])
        us = _time(fn, r, k, v, w, u, s0)
        rows.append(f"wkv6_scan_T={t},{us:.1f},us_per_call;"
                    f"{b*t*h*n/us:.1f} elems/us")
    return rows
