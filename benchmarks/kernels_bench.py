"""Kernel micro-benchmarks at paper scale (us_per_call, jnp fast path).

The Pallas kernels target TPU; on this CPU container they execute in
interpret mode (orders of magnitude slower than compiled), so wall-time
here benchmarks the jnp dispatch path and records interpret-mode cost for
reference only on tiny sizes.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.rules import build_rule_table
from repro.core.selection import dcs_select
from repro.kernels import ops as kops


def _time(fn, *args, repeats=3, **kw) -> float:
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_fuzzy_eval() -> List[str]:
    rows = []
    ev = FuzzyEvaluator()
    for p in (30, 10_000, 1_000_000):      # road, city, Tokyo-scale/3
        x = jax.random.uniform(jax.random.PRNGKey(0), (p, 4))
        fn = jax.jit(ev.evaluate)
        us = _time(fn, x)
        rows.append(f"fuzzy_eval_jnp_P={p},{us:.1f},us_per_call;"
                    f"{p/us:.1f} vehicles/us")
    return rows


def bench_neighbor_elect() -> List[str]:
    rows = []
    for n in (30, 1000, 10_000):
        pos = jax.random.uniform(jax.random.PRNGKey(1), (n,)) * 1000.0 * n / 30
        evl = jax.random.uniform(jax.random.PRNGKey(2), (n,)) * 100.0
        fn = jax.jit(lambda p, e: dcs_select(p, e, comm_range=200.0,
                                             top_m=2, e_tau=30.0))
        us = _time(fn, pos, evl)
        rows.append(f"neighbor_elect_jnp_N={n},{us:.1f},us_per_call")
    return rows


def bench_probe_fuzzy() -> List[str]:
    """Fused probe->evaluate smoke (ISSUE 5): the jnp fast path and the
    interpret-mode Pallas kernel on a small packed fleet.  The
    interpret-mode number is a correctness-path cost, not TPU time; the
    jnp number is the CPU fast path the prefix actually runs."""
    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.models.cnn import init_cnn

    rows = []
    n, per = 16, 24
    s = n * per
    rng = np.random.default_rng(0)
    images = jnp.asarray(rng.normal(size=(s, 28, 28, 1)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 10, s).astype(np.int32))
    seg = jnp.asarray(np.repeat(np.arange(n), per).astype(np.int32))
    counts = jnp.asarray(np.full(n, per, np.int32))
    aux = jnp.asarray(np.abs(rng.normal(size=(n, 3))).astype(np.float32))
    params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    ev = FuzzyEvaluator()
    table, levels = build_rule_table()
    means = jnp.asarray(ev.cfg.means, jnp.float32)
    sigmas = jnp.asarray(ev.cfg.sigmas, jnp.float32)
    centers = jnp.asarray(ev.level_centers, jnp.float32)

    for impl in ("jnp", "pallas"):
        fn = jax.jit(lambda p, im, lb, sg, ct, ax, i=impl: kops.probe_fuzzy(
            p, im, lb, sg, ct, ax, means, sigmas, table, levels, centers,
            n_clients=n, batch=128, impl=i)[1])
        us = _time(fn, params, images, labels, seg, counts, aux)
        rows.append(f"probe_fuzzy_{impl}_S={s},{us:.1f},us_per_call;"
                    f"fused probe->evaluate, N={n} clients"
                    + (";interpret mode" if impl == "pallas" else ""))
    return rows


def bench_scan_unroll() -> List[str]:
    """ISSUE 5 satellite: the shared chunk-unroll policy on the
    remaining ``lax.scan``/``fori_loop`` hot loops (before = unroll 1,
    after = the repro.scanopt policy).  Interpret-mode Pallas loops
    execute as real XLA:CPU while loops, so the before/after gap here is
    the slow path being amortized, measured on tiny shapes."""
    from repro.kernels.selective_scan import selective_scan_pallas
    from repro.kernels.wkv6 import wkv6_pallas

    rows = []
    b, t, h, n = 1, 256, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.5 + 0.5
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    s0 = jnp.zeros((b, h, n, n))
    per = {}
    for label, unroll in (("scan", 1), ("chunked", 0)):
        us = _time(lambda *a, uu=unroll: wkv6_pallas(*a, unroll=uu)[0],
                   r, k, v, w, u, s0, repeats=8)
        per[label] = us
        rows.append(f"wkv6_pallas_{label}_T={t},{us:.1f},"
                    f"us_per_call;interpret;unroll={unroll or 'policy'}")
    speedup = per["scan"] / per["chunked"]
    rows.append(f"wkv6_pallas_unroll_speedup,{speedup:.2f},"
                f"claim=chunk-unrolled kernel step loop beats the "
                f"while-loop slow path")

    di, ns = 128, 16
    x = jax.random.normal(ks[0], (b, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, di)) - 2.0)
    bm = jax.random.normal(ks[2], (b, t, ns))
    cm = jax.random.normal(ks[3], (b, t, ns))
    a = -jax.nn.softplus(jax.random.normal(ks[4], (di, ns)))
    h0 = jnp.zeros((b, di, ns))
    per = {}
    for label, unroll in (("scan", 1), ("chunked", 0)):
        us = _time(lambda *z, uu=unroll: selective_scan_pallas(
            *z, unroll=uu)[0], x, dt, bm, cm, a, h0, repeats=8)
        per[label] = us
        rows.append(f"selective_scan_pallas_{label}_T={t},{us:.1f},"
                    f"us_per_call;interpret;unroll={unroll or 'policy'}")
    speedup = per["scan"] / per["chunked"]
    rows.append(f"selective_scan_pallas_unroll_speedup,{speedup:.2f},"
                f"claim=chunk-unrolled kernel time loop beats the "
                f"while-loop slow path")
    return rows


def bench_wkv6() -> List[str]:
    rows = []
    b, h, n = 1, 4, 64
    for t in (256, 1024):
        ks = jax.random.split(jax.random.PRNGKey(3), 6)
        r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.5 + 0.5
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        s0 = jnp.zeros((b, h, n, n))
        fn = jax.jit(lambda *a: kops.wkv6(*a)[0])
        us = _time(fn, r, k, v, w, u, s0)
        rows.append(f"wkv6_scan_T={t},{us:.1f},us_per_call;"
                    f"{b*t*h*n/us:.1f} elems/us")
    return rows
