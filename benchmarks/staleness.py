"""Beyond-paper experiment: selection quality vs state staleness.

The paper's core argument for DCS is qualitative: centralized selection
acts on state that is ``tau`` seconds old (updating it faster is exactly
the Eq. 5 overhead), while DCS evaluates *fresh local* state at selection
time.  This benchmark quantifies that trade-off without training: at each
round, the centralized scheme ranks participants using throughput
predicted from their ``tau``-seconds-old positions, while the ground
truth is the evaluation at the *current* positions (vehicles at 20-33 m/s
move 100-650 m in 5-30 s — cell-edge <-> cell-center swaps).

Metric: regret = 1 - mean-true-eval(selected) / mean-true-eval(ideal
top-k), averaged over rounds.  DCS (tau = 0 by construction) appears as
the staleness-0 centralized point restricted to neighbourhoods.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.selection import ccs_fuzzy_select, dcs_select
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig

N_VEHICLES = 30
N_CLIENTS = 5
ROUNDS = 20
ROUND_PERIOD_S = 20.0


def _true_eval(ev: FuzzyEvaluator, sq, cc, lf, net, pos, seed):
    ta = net.predicted_throughput(pos, seed=seed)
    feats = np.stack([sq, ta / max(ta.max(), 1e-9), cc, lf], 1)
    return np.asarray(ev.evaluate(jnp.asarray(feats, jnp.float32)))


def bench_staleness() -> List[str]:
    rng = np.random.default_rng(7)
    ev = FuzzyEvaluator()
    net = CellularNetwork(NetworkConfig(seed=7))
    mob = FreewayMobility(MobilityConfig(n_vehicles=N_VEHICLES, seed=7))
    sq = np.where(np.arange(N_VEHICLES) < 12, 1.0, 0.01)
    cc = rng.uniform(0.25, 1.0, N_VEHICLES)
    lf = rng.uniform(0.3, 1.0, N_VEHICLES)

    rows = []
    for stale_s in (0.0, 5.0, 15.0, 30.0, 60.0):
        regrets, overlaps = [], []
        for r in range(ROUNDS):
            t = r * ROUND_PERIOD_S
            pos_now = mob.positions(t)
            pos_old = mob.positions(max(0.0, t - stale_s))
            truth = _true_eval(ev, sq, cc, lf, net, pos_now, seed=r)
            stale = _true_eval(ev, sq, cc, lf, net, pos_old, seed=r)
            mask = np.asarray(ccs_fuzzy_select(jnp.asarray(stale),
                                               N_CLIENTS))
            ideal = np.sort(truth)[-N_CLIENTS:].mean()
            got = truth[mask > 0].mean()
            regrets.append(1.0 - got / max(ideal, 1e-9))
            top = set(np.argsort(-truth)[:N_CLIENTS])
            overlaps.append(len(top & set(np.where(mask)[0])) / N_CLIENTS)
        rows.append(
            f"staleness_ccs_regret@tau={stale_s:g},{np.mean(regrets):.4f},"
            f"top{N_CLIENTS}_overlap={np.mean(overlaps):.2f}")

    # DCS reference: fresh state, neighbourhood-restricted
    regrets = []
    for r in range(ROUNDS):
        t = r * ROUND_PERIOD_S
        pos_now = mob.positions(t)
        truth = _true_eval(ev, sq, cc, lf, net, pos_now, seed=r)
        mask = np.asarray(dcs_select(jnp.asarray(pos_now),
                                     jnp.asarray(truth),
                                     comm_range=200.0, top_m=2, e_tau=30.0))
        k = max(int(mask.sum()), 1)
        ideal = np.sort(truth)[-k:].mean()
        got = truth[mask > 0].mean() if mask.sum() else 0.0
        regrets.append(1.0 - got / max(ideal, 1e-9))
    rows.append(f"staleness_dcs_regret,{np.mean(regrets):.4f},"
                "fresh local state, neighbourhood top-2")
    return rows


# -- accuracy vs staleness lambda (event-driven server, ISSUE 6) -----------

_LAMBDAS = (0.0, 0.5, 2.0)
_LAMBDA_ROUNDS = 3


def bench_staleness_lambda() -> List[str]:
    """End-to-end accuracy of the event-driven server's staleness-
    weighted aggregation across decay lambdas.

    A tightened Eq. 6 deadline makes most selected clients stragglers;
    ``staleness="weighted"`` trains them anyway and folds
    ``1/(1 + lambda * delay_rounds)`` into their FedAvg weight.
    ``lambda = 0`` aggregates every late update at full weight (maximum
    information, maximum staleness noise); large lambdas approach the
    hard-deadline drop policy.  Reported per lambda: final accuracy,
    the stale-update fraction and the effective cohort size."""
    from repro.fl.partition import PartitionConfig
    from repro.fl.rounds import FLSimConfig, FLSimulation
    from repro.fl.runconfig import RunConfig

    rows = []
    for lam in _LAMBDAS:
        cfg = FLSimConfig(
            scheme="ccs-fuzzy", local_epochs=1, deadline_s=25.0,
            partition=PartitionConfig(n_clients=10, big_quantity=120,
                                      small_quantity=40,
                                      classes_per_client=4, seed=0),
            samples_per_class=400,
            mobility=MobilityConfig(n_vehicles=10, seed=0), seed=0)
        sim = FLSimulation(cfg, run=RunConfig(
            staleness="weighted", staleness_lambda=lam))
        hist = sim.run(_LAMBDA_ROUNDS)
        stale = np.mean([h["stale_frac"] for h in hist])
        eff = np.mean([h["n_effective"] for h in hist])
        rows.append(
            f"staleness_lambda_acc@lam={lam:g},"
            f"{hist[-1]['accuracy']:.4f},"
            f"stale_frac={stale:.2f} n_effective={eff:.2f} "
            f"({_LAMBDA_ROUNDS} rounds, deadline 25s)")
    return rows
