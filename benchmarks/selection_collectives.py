"""Beyond-paper benchmark: the selection protocols' communication cost in
*compiled HLO collective bytes* — the mesh-native restatement of Fig. 2/9.

Runs in a subprocess with 16 forced host devices (so collectives
materialize) and compares per-device collective bytes of:
  - ccs_state_gather  (full state vector to the server)  ~ O(N * state_dim)
  - ccs_fuzzy_gather  (scalar evaluations to the server)  ~ O(N)
  - dcs_neighbor_exchange (boundary window to 2 neighbours) ~ O(window)
"""
from __future__ import annotations

import json
import subprocess
import sys
from typing import List

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
import jax.numpy as jnp
from repro.core.fuzzy import FuzzyEvaluator
from repro.core.protocol import (make_ccs_fuzzy_gather, make_ccs_state_gather,
                                 make_dcs_neighbor_exchange)
from repro.launch import hlo_cost

mesh = jax.make_mesh((16,), ("data",))
N, SD, WIN = 1_048_576, 25, 1024       # 1M vehicles, 25-float state
states = jax.ShapeDtypeStruct((N, SD), jnp.float32)
ev = jax.ShapeDtypeStruct((N,), jnp.float32)
pos = jax.ShapeDtypeStruct((N,), jnp.float32)

out = {}
g = jax.jit(make_ccs_state_gather(mesh, FuzzyEvaluator(), 1000, SD)) \
    .lower(states).compile()
out["ccs_state_gather"] = hlo_cost.analyze(g.as_text()).collective_bytes
f = jax.jit(make_ccs_fuzzy_gather(mesh, 1000)).lower(ev).compile()
out["ccs_fuzzy_gather"] = hlo_cost.analyze(f.as_text()).collective_bytes
d = jax.jit(make_dcs_neighbor_exchange(mesh, comm_range=200.0, top_m=2,
                                       e_tau=30.0, window=WIN)) \
    .lower(pos, ev).compile()
out["dcs_neighbor_exchange"] = hlo_cost.analyze(d.as_text()).collective_bytes
print(json.dumps(out))
"""


def bench_selection_collectives() -> List[str]:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"}, timeout=540)
    if proc.returncode != 0:
        return [f"selection_collectives_error,1,{proc.stderr[-200:]!r}"]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for k, v in data.items():
        rows.append(f"collective_bytes_{k},{v:.3e},per-device;N=1048576")
    if data["dcs_neighbor_exchange"] > 0:
        ratio = data["ccs_state_gather"] / data["dcs_neighbor_exchange"]
        rows.append(f"collective_ratio_ccs_over_dcs,{ratio:.1f},"
                    "Eq.5 elimination, in compiled HLO bytes")
    return rows
