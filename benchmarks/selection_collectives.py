"""Beyond-paper benchmark: the selection protocols' communication cost in
*compiled HLO collective bytes* — the mesh-native restatement of Fig. 2/9
— plus the mesh-sharded selection prefix's per-device scaling.

Runs in a subprocess with forced host devices (so collectives
materialize).  ``bench_selection_collectives`` compares per-device
collective bytes of:
  - ccs_state_gather  (full state vector to the server)  ~ O(N * state_dim)
  - ccs_fuzzy_gather  (scalar evaluations to the server)  ~ O(N)
  - dcs_neighbor_exchange (boundary window to 2 neighbours) ~ O(window)

``bench_prefix_sharding`` runs ``selection_prefix_sharded`` at a fixed
fleet size on 1/2/4/8-device client meshes and records the *measured*
per-device bytes of the client-axis arrays (statics shards + packed
probe region, via ``addressable_shards``) and the prefix wall time —
the per-device client-axis memory must shrink ~1/K with mesh size.

``bench_windowed_scaling`` (ISSUE 9) is the N-scaling curve of the
windowed neighbour-exchange election vs the dense full-gather seam, at
fixed vehicle density (road length grows with N) on a 16-device mesh,
N up to 10^6 emulated vehicles:

- per-device collective bytes split by kind from compiled HLO — the
  halo ``collective-permute`` bytes must stay FLAT in N (the window is
  density-determined), while the full gather's ``all-gather`` bytes
  grow O(N); the bucketing ``all-to-all`` is O(N/K) layout movement
  and is reported separately, never folded into the halo number;
- measured election wall time for the windowed path up to
  ``REPRO_WINDOWED_MAXN`` (the dense gather election is O(N^2) compute
  and only executes at the smallest N, where the windowed mask is also
  asserted bit-identical to the dense reference);
- CI gates: halo bytes flat (max/min < 1.6) and windowed total bytes
  under the gather bytes at the largest executed N.

Results append to the cumulative ``BENCH_selection.json`` artifact
(profile "windowed-scaling") alongside the prefix-fusion trajectory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
import jax.numpy as jnp
from repro.core.fuzzy import FuzzyEvaluator
from repro.core.protocol import (make_ccs_fuzzy_gather, make_ccs_state_gather,
                                 make_dcs_neighbor_exchange)
from repro.launch import hlo_cost

mesh = jax.make_mesh((16,), ("data",))
N, SD, WIN = 1_048_576, 25, 1024       # 1M vehicles, 25-float state
states = jax.ShapeDtypeStruct((N, SD), jnp.float32)
ev = jax.ShapeDtypeStruct((N,), jnp.float32)
pos = jax.ShapeDtypeStruct((N,), jnp.float32)

out = {}
g = jax.jit(make_ccs_state_gather(mesh, FuzzyEvaluator(), 1000, SD)) \
    .lower(states).compile()
out["ccs_state_gather"] = hlo_cost.analyze(g.as_text()).collective_bytes
f = jax.jit(make_ccs_fuzzy_gather(mesh, 1000)).lower(ev).compile()
out["ccs_fuzzy_gather"] = hlo_cost.analyze(f.as_text()).collective_bytes
d = jax.jit(make_dcs_neighbor_exchange(mesh, comm_range=200.0, top_m=2,
                                       e_tau=30.0, window=WIN)) \
    .lower(pos, ev).compile()
out["dcs_neighbor_exchange"] = hlo_cost.analyze(d.as_text()).collective_bytes
print(json.dumps(out))
"""


def bench_selection_collectives() -> List[str]:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"}, timeout=540)
    if proc.returncode != 0:
        return [f"selection_collectives_error,1,{proc.stderr[-200:]!r}"]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for k, v in data.items():
        rows.append(f"collective_bytes_{k},{v:.3e},per-device;N=1048576")
    if data["dcs_neighbor_exchange"] > 0:
        ratio = data["ccs_state_gather"] / data["dcs_neighbor_exchange"]
        rows.append(f"collective_ratio_ccs_over_dcs,{ratio:.1f},"
                    "Eq.5 elimination, in compiled HLO bytes")
    return rows


_CHILD_PREFIX = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.fl import pipeline
from repro.fl.network import NetworkConfig
from repro.fl.timing import TimingConfig
from repro.launch.mesh import make_clients_mesh
from repro.models.cnn import init_cnn

N, B, PER = 256, 64, 64            # clients, probe batch, samples/client
rng = np.random.default_rng(0)
ev = FuzzyEvaluator(FuzzyEvaluatorConfig())
f32 = jnp.float32
S = N * PER                        # one whole probe batch per client
st = pipeline.RoundStatics(
    x0=jnp.asarray(rng.uniform(0, 1000.0, N), f32),
    speeds=jnp.asarray(rng.uniform(20, 33, N), f32),
    jitter_phase=jnp.asarray(rng.uniform(0, 6.28, N), f32),
    slowdown=jnp.asarray(rng.uniform(1, 4, N), f32),
    n_valid=jnp.asarray(np.full(N, PER), f32),
    probe_images=jnp.asarray(
        rng.normal(size=(S, 28, 28, 1)).astype(np.float32)),
    probe_labels=jnp.asarray(rng.integers(0, 10, S).astype(np.int32)),
    probe_seg=jnp.asarray(np.repeat(np.arange(N), PER).astype(np.int32)),
    probe_counts=jnp.asarray(np.full(N, PER, np.int32)),
    means=jnp.asarray(ev.cfg.means, f32),
    sigmas=jnp.asarray(ev.cfg.sigmas, f32),
    level_centers=jnp.asarray(ev.level_centers, f32))
cfg = pipeline.StageConfig(
    scheme="dcs", n_clients=N, comm_range_m=200.0, top_m=2, e_tau=30.0,
    n_clients_central=5, model_bytes=5.2e6, road_length_m=1000.0,
    speed_jitter=1.0, timing=TimingConfig(epochs=1, batch_size=20,
                                          deadline_s=60.0),
    network=NetworkConfig(), probe_batch=B)
params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
key = jax.random.PRNGKey(1)
net_key = jax.random.PRNGKey(2)

# the client-axis arrays the prefix shards, with their partition specs
CLIENT_LEAVES = [
    (st.x0, P("clients")), (st.speeds, P("clients")),
    (st.jitter_phase, P("clients")), (st.slowdown, P("clients")),
    (st.n_valid, P("clients")),
    (st.probe_images, P("clients", None, None, None)),
    (st.probe_labels, P("clients")), (st.probe_seg, P("clients")),
]

out = {}
for k in (1, 2, 4, 8):
    mesh = make_clients_mesh(k)
    per_dev = {}
    for arr, spec in CLIENT_LEAVES:
        sharded = jax.device_put(arr, NamedSharding(mesh, spec))
        for sh in sharded.addressable_shards:
            per_dev[sh.device.id] = (per_dev.get(sh.device.id, 0)
                                     + sh.data.nbytes)
    res = pipeline.selection_prefix_sharded(
        st, params, jnp.int32(0), key, net_key, cfg=cfg, mesh=mesh)
    jax.block_until_ready(res)                     # compile
    reps = 3
    t0 = time.perf_counter()
    for r in range(1, reps + 1):
        jax.block_until_ready(pipeline.selection_prefix_sharded(
            st, params, jnp.int32(r), key, net_key, cfg=cfg, mesh=mesh))
    out[str(k)] = {"bytes_per_device": max(per_dev.values()),
                   "wall_ms": (time.perf_counter() - t0) / reps * 1e3,
                   "n_selected": int(res["n_selected"])}
print(json.dumps(out))
"""


def bench_prefix_sharding() -> List[str]:
    # raise (-> benchmarks/run.py exits nonzero) instead of an error row:
    # the CI test-sharded step gates on this bench, so a crashed sharded
    # prefix or a silently-replicated client axis must fail the job
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_PREFIX], capture_output=True,
        text=True, env={**__import__("os").environ, "PYTHONPATH": "src"},
        timeout=540)
    if proc.returncode != 0:
        raise RuntimeError(
            f"prefix_sharding child failed:\n{proc.stderr[-2000:]}")
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for k, d in sorted(data.items(), key=lambda kv: int(kv[0])):
        rows.append(f"prefix_clientaxis_bytes_per_device_k{k},"
                    f"{d['bytes_per_device']:.3e},"
                    f"N=256;64 probe samples/client")
        rows.append(f"prefix_wall_ms_k{k},{d['wall_ms']:.1f},"
                    f"sharded selection prefix, {k} emulated devices")
    shrink = (data["1"]["bytes_per_device"]
              / max(data["8"]["bytes_per_device"], 1))
    if shrink < 4.0:                     # exact split measures 8.0
        raise RuntimeError(
            f"per-device client-axis memory shrank only {shrink:.2f}x "
            f"from 1 to 8 shards — the client partition is replicating")
    rows.append(f"prefix_clientaxis_shrink_1_to_8,{shrink:.2f},"
                "per-device client-axis memory ratio (want ~8)")
    return rows


_CHILD_WINDOWED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
from repro.core import elect as celect
from repro.kernels import ref as kref
from repro.launch import hlo_cost
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import CLIENT_AXIS

K = 16
CR, TOP_M, E_TAU = 200.0, 2, 30.0
WALL_MAXN = int(os.environ.get("REPRO_WINDOWED_MAXN", "262144"))
BYTES_MAXN = 1_048_576
NS = [n for n in (4096, 16384, 65536, 262144, 1_048_576)
      if n <= max(BYTES_MAXN, WALL_MAXN)]
mesh = make_clients_mesh(K)
sh = NamedSharding(mesh, P(CLIENT_AXIS))


def windowed_fn(n, road, window, cap):
    shard_n = n // K

    def f(pos, ev, gid, valid):
        mask, ovf = celect.ring_halo_elect(
            pos, ev, gid, valid, axis=CLIENT_AXIS, n=n, n_shards=K,
            shard_n=shard_n, comm_range=CR, top_m=TOP_M, e_tau=E_TAU,
            road_length=road, window=window, capacity=cap)
        return mask, jax.lax.pmax(ovf, CLIENT_AXIS)

    return jax.jit(shard_map(f, mesh=mesh,
                             in_specs=(P(CLIENT_AXIS),) * 4,
                             out_specs=(P(CLIENT_AXIS), P())))


def gather_bytes_fn(n):
    # the dense seam's collectives alone (the O(N^2) election compute is
    # omitted so the function stays compilable/executable at any N — the
    # all_gather bytes are what the windowed path eliminates)
    shard_n = n // K

    def f(pos, ev):
        pg = jax.lax.all_gather(pos, CLIENT_AXIS, tiled=True)
        eg = jax.lax.all_gather(ev, CLIENT_AXIS, tiled=True)
        i = jax.lax.axis_index(CLIENT_AXIS)
        merged = pg + eg                 # consume both gathers
        return jax.lax.dynamic_slice_in_dim(merged, i * shard_n, shard_n)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(CLIENT_AXIS),) * 2,
                             out_specs=P(CLIENT_AXIS)))


def gather_elect_fn(n):
    # the real dense election on gathered vectors (wall-clock reference;
    # O(N^2) — executed at the smallest N only)
    shard_n = n // K

    def f(pos, ev):
        pg = jax.lax.all_gather(pos, CLIENT_AXIS, tiled=True)
        eg = jax.lax.all_gather(ev, CLIENT_AXIS, tiled=True)
        mask = kref.neighbor_elect_ref(pg, eg, comm_range=CR, top_m=TOP_M,
                                       e_tau=E_TAU)
        i = jax.lax.axis_index(CLIENT_AXIS)
        return jax.lax.dynamic_slice_in_dim(mask, i * shard_n, shard_n)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(CLIENT_AXIS),) * 2,
                             out_specs=P(CLIENT_AXIS)))


def kind_bytes(compiled):
    cost = hlo_cost.analyze(compiled.as_text())
    return {"total": cost.collective_bytes, **cost.by_kind}


out = {}
rng = np.random.default_rng(0)
for n in NS:
    road = float(n)                      # fixed density: 1 vehicle / m
    window = celect.auto_window(n, CR, road)
    cap = celect.auto_capacity(n // K, K)
    pos_np = rng.uniform(0.0, road, n).astype(np.float32)
    ev_np = rng.uniform(0.0, 100.0, n).astype(np.float32)
    shapes = (jax.ShapeDtypeStruct((n,), jnp.float32),
              jax.ShapeDtypeStruct((n,), jnp.float32),
              jax.ShapeDtypeStruct((n,), jnp.int32),
              jax.ShapeDtypeStruct((n,), jnp.bool_))
    wfn = windowed_fn(n, road, window, cap)
    wc = wfn.lower(*shapes).compile()
    gc = gather_bytes_fn(n).lower(*shapes[:2]).compile()
    rec = {"window": window, "capacity": cap,
           "windowed": kind_bytes(wc), "gather": kind_bytes(gc)}
    if n <= WALL_MAXN:                   # execute the windowed election
        args = (jax.device_put(pos_np, sh), jax.device_put(ev_np, sh),
                jax.device_put(np.arange(n, dtype=np.int32), sh),
                jax.device_put(np.ones(n, np.bool_), sh))
        mask, ovf = wc(*args)
        jax.block_until_ready(mask)
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(wc(*args)[0])
        rec["windowed_wall_ms"] = (time.perf_counter() - t0) / reps * 1e3
        rec["overflow"] = int(ovf)
    if n == NS[0] and "overflow" in rec:  # dense ref: wall + parity
        ge = gather_elect_fn(n).lower(*shapes[:2]).compile()
        mask_ref = ge(args[0], args[1])
        jax.block_until_ready(mask_ref)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(ge(args[0], args[1]))
        rec["gather_wall_ms"] = (time.perf_counter() - t0) / 3 * 1e3
        if rec["overflow"] == 0 and not bool(
                np.array_equal(np.asarray(mask), np.asarray(mask_ref))):
            raise SystemExit("windowed mask != dense election at N=%d "
                             "with overflow=0" % n)
        rec["parity_checked"] = int(rec["overflow"] == 0)
    out[str(n)] = rec
print(json.dumps(out))
"""


def _append_selection_artifact(profile: str, cells: List[Dict]) -> None:
    path = os.environ.get("REPRO_BENCH_SELECTION_OUT",
                          "BENCH_selection.json")
    data = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {"runs": []}
    data.setdefault("runs", []).append(
        {"unix_time": int(time.time()), "profile": profile, "cells": cells})
    # atomic append-rewrite: a killed bench never tears the cumulative
    # artifact (repro.ioutil, ISSUE 10)
    from repro.ioutil import write_atomic_json
    write_atomic_json(path, data, indent=1)


def bench_windowed_scaling() -> List[str]:
    """Windowed-vs-gather election scaling (raises on a lost gate so CI
    fails the job, same policy as ``bench_prefix_sharding``)."""
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_WINDOWED], capture_output=True,
        text=True, env={**os.environ, "PYTHONPATH": "src"}, timeout=3000)
    if proc.returncode != 0:
        raise RuntimeError(
            f"windowed_scaling child failed:\n{proc.stderr[-2000:]}\n"
            f"{proc.stdout[-500:]}")
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows, cells = [], []
    halo, executed = {}, []
    for n_s, rec in sorted(data.items(), key=lambda kv: int(kv[0])):
        n = int(n_s)
        wb, gb = rec["windowed"], rec["gather"]
        halo[n] = wb.get("collective-permute", 0.0)
        rows.append(f"windowed_halo_bytes_n{n},{halo[n]:.3e},"
                    f"per-device ppermute halo; window={rec['window']}")
        rows.append(f"windowed_a2a_bytes_n{n},"
                    f"{wb.get('all-to-all', 0.0):.3e},"
                    f"per-device bucketing layout movement (O(N/K))")
        rows.append(f"windowed_total_bytes_n{n},{wb['total']:.3e},"
                    f"per-device, all collectives")
        rows.append(f"gather_bytes_n{n},{gb['total']:.3e},"
                    f"per-device dense-seam all_gather (O(N))")
        if "windowed_wall_ms" in rec:
            executed.append(n)
            rows.append(f"windowed_elect_wall_ms_n{n},"
                        f"{rec['windowed_wall_ms']:.1f},"
                        f"16 emulated devices; overflow="
                        f"{rec['overflow']}")
        if "gather_wall_ms" in rec:
            rows.append(f"gather_elect_wall_ms_n{n},"
                        f"{rec['gather_wall_ms']:.1f},"
                        f"dense O(N^2) election on gathered vectors")
        cells.append({"n": n, **rec})
    # gate 1: halo bytes flat in N at fixed density (the whole point —
    # the exchanged window is determined by density, not fleet size)
    hi, lo = max(halo.values()), max(min(halo.values()), 1.0)
    rows.append(f"windowed_halo_flatness,{hi / lo:.3f},"
                "max/min per-device halo bytes across N (want ~1)")
    if hi / lo >= 1.6:
        raise RuntimeError(
            f"halo bytes grew {hi / lo:.2f}x across N — the neighbour "
            f"exchange is not O(window) per device")
    # gate 2: the win at the largest executed N — total windowed bytes
    # (bucketing included) under the dense seam's gather bytes
    gate_n = max(executed)
    wt = data[str(gate_n)]["windowed"]["total"]
    gt = data[str(gate_n)]["gather"]["total"]
    rows.append(f"windowed_bytes_win_n{gate_n},{gt / max(wt, 1.0):.2f},"
                "gather/windowed per-device collective bytes (want > 1)")
    if wt >= gt:
        raise RuntimeError(
            f"windowed election moved {wt:.3e} collective B/device at "
            f"N={gate_n}, not under the gather seam's {gt:.3e}")
    _append_selection_artifact("windowed-scaling", cells)
    return rows
