"""Beyond-paper benchmark: the selection protocols' communication cost in
*compiled HLO collective bytes* — the mesh-native restatement of Fig. 2/9
— plus the mesh-sharded selection prefix's per-device scaling.

Runs in a subprocess with forced host devices (so collectives
materialize).  ``bench_selection_collectives`` compares per-device
collective bytes of:
  - ccs_state_gather  (full state vector to the server)  ~ O(N * state_dim)
  - ccs_fuzzy_gather  (scalar evaluations to the server)  ~ O(N)
  - dcs_neighbor_exchange (boundary window to 2 neighbours) ~ O(window)

``bench_prefix_sharding`` runs ``selection_prefix_sharded`` at a fixed
fleet size on 1/2/4/8-device client meshes and records the *measured*
per-device bytes of the client-axis arrays (statics shards + packed
probe region, via ``addressable_shards``) and the prefix wall time —
the per-device client-axis memory must shrink ~1/K with mesh size.
"""
from __future__ import annotations

import json
import subprocess
import sys
from typing import List

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
import jax.numpy as jnp
from repro.core.fuzzy import FuzzyEvaluator
from repro.core.protocol import (make_ccs_fuzzy_gather, make_ccs_state_gather,
                                 make_dcs_neighbor_exchange)
from repro.launch import hlo_cost

mesh = jax.make_mesh((16,), ("data",))
N, SD, WIN = 1_048_576, 25, 1024       # 1M vehicles, 25-float state
states = jax.ShapeDtypeStruct((N, SD), jnp.float32)
ev = jax.ShapeDtypeStruct((N,), jnp.float32)
pos = jax.ShapeDtypeStruct((N,), jnp.float32)

out = {}
g = jax.jit(make_ccs_state_gather(mesh, FuzzyEvaluator(), 1000, SD)) \
    .lower(states).compile()
out["ccs_state_gather"] = hlo_cost.analyze(g.as_text()).collective_bytes
f = jax.jit(make_ccs_fuzzy_gather(mesh, 1000)).lower(ev).compile()
out["ccs_fuzzy_gather"] = hlo_cost.analyze(f.as_text()).collective_bytes
d = jax.jit(make_dcs_neighbor_exchange(mesh, comm_range=200.0, top_m=2,
                                       e_tau=30.0, window=WIN)) \
    .lower(pos, ev).compile()
out["dcs_neighbor_exchange"] = hlo_cost.analyze(d.as_text()).collective_bytes
print(json.dumps(out))
"""


def bench_selection_collectives() -> List[str]:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"}, timeout=540)
    if proc.returncode != 0:
        return [f"selection_collectives_error,1,{proc.stderr[-200:]!r}"]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for k, v in data.items():
        rows.append(f"collective_bytes_{k},{v:.3e},per-device;N=1048576")
    if data["dcs_neighbor_exchange"] > 0:
        ratio = data["ccs_state_gather"] / data["dcs_neighbor_exchange"]
        rows.append(f"collective_ratio_ccs_over_dcs,{ratio:.1f},"
                    "Eq.5 elimination, in compiled HLO bytes")
    return rows


_CHILD_PREFIX = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.fl import pipeline
from repro.fl.network import NetworkConfig
from repro.fl.timing import TimingConfig
from repro.launch.mesh import make_clients_mesh
from repro.models.cnn import init_cnn

N, B, PER = 256, 64, 64            # clients, probe batch, samples/client
rng = np.random.default_rng(0)
ev = FuzzyEvaluator(FuzzyEvaluatorConfig())
f32 = jnp.float32
S = N * PER                        # one whole probe batch per client
st = pipeline.RoundStatics(
    x0=jnp.asarray(rng.uniform(0, 1000.0, N), f32),
    speeds=jnp.asarray(rng.uniform(20, 33, N), f32),
    jitter_phase=jnp.asarray(rng.uniform(0, 6.28, N), f32),
    slowdown=jnp.asarray(rng.uniform(1, 4, N), f32),
    n_valid=jnp.asarray(np.full(N, PER), f32),
    probe_images=jnp.asarray(
        rng.normal(size=(S, 28, 28, 1)).astype(np.float32)),
    probe_labels=jnp.asarray(rng.integers(0, 10, S).astype(np.int32)),
    probe_seg=jnp.asarray(np.repeat(np.arange(N), PER).astype(np.int32)),
    probe_counts=jnp.asarray(np.full(N, PER, np.int32)),
    means=jnp.asarray(ev.cfg.means, f32),
    sigmas=jnp.asarray(ev.cfg.sigmas, f32),
    level_centers=jnp.asarray(ev.level_centers, f32))
cfg = pipeline.StageConfig(
    scheme="dcs", n_clients=N, comm_range_m=200.0, top_m=2, e_tau=30.0,
    n_clients_central=5, model_bytes=5.2e6, road_length_m=1000.0,
    speed_jitter=1.0, timing=TimingConfig(epochs=1, batch_size=20,
                                          deadline_s=60.0),
    network=NetworkConfig(), probe_batch=B)
params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
key = jax.random.PRNGKey(1)
net_key = jax.random.PRNGKey(2)

# the client-axis arrays the prefix shards, with their partition specs
CLIENT_LEAVES = [
    (st.x0, P("clients")), (st.speeds, P("clients")),
    (st.jitter_phase, P("clients")), (st.slowdown, P("clients")),
    (st.n_valid, P("clients")),
    (st.probe_images, P("clients", None, None, None)),
    (st.probe_labels, P("clients")), (st.probe_seg, P("clients")),
]

out = {}
for k in (1, 2, 4, 8):
    mesh = make_clients_mesh(k)
    per_dev = {}
    for arr, spec in CLIENT_LEAVES:
        sharded = jax.device_put(arr, NamedSharding(mesh, spec))
        for sh in sharded.addressable_shards:
            per_dev[sh.device.id] = (per_dev.get(sh.device.id, 0)
                                     + sh.data.nbytes)
    res = pipeline.selection_prefix_sharded(
        st, params, jnp.int32(0), key, net_key, cfg=cfg, mesh=mesh)
    jax.block_until_ready(res)                     # compile
    reps = 3
    t0 = time.perf_counter()
    for r in range(1, reps + 1):
        jax.block_until_ready(pipeline.selection_prefix_sharded(
            st, params, jnp.int32(r), key, net_key, cfg=cfg, mesh=mesh))
    out[str(k)] = {"bytes_per_device": max(per_dev.values()),
                   "wall_ms": (time.perf_counter() - t0) / reps * 1e3,
                   "n_selected": int(res["n_selected"])}
print(json.dumps(out))
"""


def bench_prefix_sharding() -> List[str]:
    # raise (-> benchmarks/run.py exits nonzero) instead of an error row:
    # the CI test-sharded step gates on this bench, so a crashed sharded
    # prefix or a silently-replicated client axis must fail the job
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_PREFIX], capture_output=True,
        text=True, env={**__import__("os").environ, "PYTHONPATH": "src"},
        timeout=540)
    if proc.returncode != 0:
        raise RuntimeError(
            f"prefix_sharding child failed:\n{proc.stderr[-2000:]}")
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for k, d in sorted(data.items(), key=lambda kv: int(kv[0])):
        rows.append(f"prefix_clientaxis_bytes_per_device_k{k},"
                    f"{d['bytes_per_device']:.3e},"
                    f"N=256;64 probe samples/client")
        rows.append(f"prefix_wall_ms_k{k},{d['wall_ms']:.1f},"
                    f"sharded selection prefix, {k} emulated devices")
    shrink = (data["1"]["bytes_per_device"]
              / max(data["8"]["bytes_per_device"], 1))
    if shrink < 4.0:                     # exact split measures 8.0
        raise RuntimeError(
            f"per-device client-axis memory shrank only {shrink:.2f}x "
            f"from 1 to 8 shards — the client partition is replicating")
    rows.append(f"prefix_clientaxis_shrink_1_to_8,{shrink:.2f},"
                "per-device client-axis memory ratio (want ~8)")
    return rows
