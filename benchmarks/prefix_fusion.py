"""Fused selection fast path: end-to-end prefix wall time, fused vs
unfused, on Table-3-shaped fleets at N in {96, 256, 1024}.

Measures the ISSUE 5 tentpole claim end to end: the same
``selection_prefix`` program with ``fused_probe`` off (the PR-4
batch-aligned probe packing + staged probe/evaluate ops) vs on (tight
probe packing + ``kops.probe_fuzzy``).  On CPU the win is dominated by
the dead probe rows the tight pack eliminates — a 45-sample Table-3
client pays 45 forward rows instead of a full 128-row aligned batch —
with the fused single-subgraph evaluate riding along; on TPU the same
flag additionally collapses the chain into one Pallas launch.

Each (N, variant) cell is AOT-compiled (``.lower().compile()``) so the
timed call is pure execution, and reports:

- prefix wall seconds;
- probe GFLOP/s over the rows the variant actually processes (forward
  FLOPs of the paper CNN per row — the fused variant processes fewer
  rows for the same fleet, which is the point);
- the fused-vs-unfused wall ratio.

Results append to a cumulative ``BENCH_selection.json`` (override the
path with ``REPRO_BENCH_SELECTION_OUT``) so future PRs diff against a
recorded trajectory; CI uploads the file as an artifact.  The bench
RAISES if the N=256 speedup falls under the 1.3x acceptance floor, so
the CI step gates instead of just printing.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.fl import pipeline
from repro.fl.network import NetworkConfig
from repro.fl.timing import TimingConfig
from repro.models.cnn import init_cnn

PROBE_BATCH = 128
MIN_RATIO_N256 = 1.3

# Table-3-shaped fleets: 12 data-rich vehicles, the rest data-poor.
# N=1024 trims the per-client probe so the unfused baseline stays
# CI-affordable (the *ratio* is shape-driven, not size-driven).
FLEETS = {96: (256, 45), 256: (256, 45), 1024: (256, 24)}
REPS = {96: 2, 256: 2, 1024: 1}

# forward MACs per probe row of the paper CNN (conv1 + conv2 + fc1 + fc2)
_MACS_PER_ROW = (28 * 28 * 25 * 1 * 32 + 14 * 14 * 25 * 32 * 64
                 + 3136 * 512 + 512 * 10)


def _pack(counts: np.ndarray, align: int,
          rng: np.random.Generator) -> Tuple[np.ndarray, ...]:
    """A packed probe tensor set mirroring FLSimulation's packer:
    per-client rows padded to ``align`` with sentinel seg == N."""
    n = len(counts)
    ims, lbs, segs = [], [], []
    for i, t in enumerate(counts):
        t = int(t)
        ims.append(rng.normal(size=(t, 28, 28, 1)).astype(np.float32))
        lbs.append(rng.integers(0, 10, t).astype(np.int32))
        segs.append(np.full(t, i, np.int32))
        pad = (-t) % align
        if pad:
            ims.append(np.zeros((pad, 28, 28, 1), np.float32))
            lbs.append(np.zeros(pad, np.int32))
            segs.append(np.full(pad, n, np.int32))
    return np.concatenate(ims), np.concatenate(lbs), np.concatenate(segs)


def _statics_cfg(n: int, fused: bool) -> Tuple[pipeline.RoundStatics,
                                               pipeline.StageConfig, int]:
    big, small = FLEETS[n]
    counts = np.full(n, small, np.int64)
    counts[:12] = big
    rng = np.random.default_rng(0)
    align = 1 if fused else PROBE_BATCH
    pim, plb, pseg = _pack(counts, align, rng)
    ev = FuzzyEvaluator(FuzzyEvaluatorConfig())
    f32 = jnp.float32
    st = pipeline.RoundStatics(
        x0=jnp.asarray(rng.uniform(0, 2000.0, n), f32),
        speeds=jnp.asarray(rng.uniform(20, 33, n), f32),
        jitter_phase=jnp.asarray(rng.uniform(0, 6.28, n), f32),
        slowdown=jnp.asarray(rng.uniform(1, 4, n), f32),
        n_valid=jnp.asarray(counts, f32),
        probe_images=jnp.asarray(pim),
        probe_labels=jnp.asarray(plb),
        probe_seg=jnp.asarray(pseg),
        probe_counts=jnp.asarray(counts.astype(np.int32)),
        means=jnp.asarray(ev.cfg.means, f32),
        sigmas=jnp.asarray(ev.cfg.sigmas, f32),
        level_centers=jnp.asarray(ev.level_centers, f32))
    cfg = pipeline.StageConfig(
        scheme="dcs", n_clients=n, comm_range_m=200.0, top_m=2, e_tau=30.0,
        n_clients_central=5, model_bytes=5.2e6, road_length_m=2000.0,
        speed_jitter=1.0,
        timing=TimingConfig(epochs=1, batch_size=20, deadline_s=60.0),
        network=NetworkConfig(), probe_batch=PROBE_BATCH, fused_probe=fused)
    # rows the probe forward actually executes: the packed sample axis,
    # padded to whole probe batches inside the loss op
    rows = -(-pim.shape[0] // PROBE_BATCH) * PROBE_BATCH
    return st, cfg, rows


def _artifact_path() -> str:
    return os.environ.get("REPRO_BENCH_SELECTION_OUT",
                          "BENCH_selection.json")


def _append_artifact(cells: List[Dict]) -> str:
    path = _artifact_path()
    data = {"runs": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            data = {"runs": []}
    data.setdefault("runs", []).append(
        {"unix_time": int(time.time()), "profile": "table3-shaped",
         "probe_batch": PROBE_BATCH, "cells": cells})
    # atomic append-rewrite: a killed bench never tears the cumulative
    # artifact (repro.ioutil, ISSUE 10)
    from repro.ioutil import write_atomic_json
    write_atomic_json(path, data, indent=1)
    return path


def bench_prefix_fusion() -> List[str]:
    params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    key = jax.random.PRNGKey(1)
    net_key = jax.random.PRNGKey(2)
    rows_out: List[str] = []
    cells: List[Dict] = []
    masks: Dict[int, Dict[str, np.ndarray]] = {}
    for n in sorted(FLEETS):
        cell: Dict = {"n_clients": n}
        masks[n] = {}
        for fused in (False, True):
            st, cfg, probe_rows = _statics_cfg(n, fused)
            compiled = pipeline.selection_prefix.lower(
                st, params, jnp.int32(0), key, net_key, cfg=cfg).compile()
            reps = REPS[n]
            t0 = time.perf_counter()
            for r in range(reps):
                out = compiled(st, params, jnp.int32(r), key, net_key)
                jax.block_until_ready(out)
            wall = (time.perf_counter() - t0) / reps
            masks[n][("fused" if fused else "unfused")] = \
                np.asarray(jax.device_get(out["mask"]))
            gflops = 2.0 * _MACS_PER_ROW * probe_rows / wall / 1e9
            tag = "fused" if fused else "unfused"
            cell[f"prefix_wall_s_{tag}"] = round(wall, 4)
            cell[f"probe_rows_{tag}"] = int(probe_rows)
            cell[f"probe_gflops_{tag}"] = round(gflops, 2)
            rows_out.append(
                f"prefix_{tag}_wall_s_N={n},{wall:.3f},"
                f"{probe_rows} probe rows;{gflops:.1f} GFLOP/s")
        ratio = cell["prefix_wall_s_unfused"] / cell["prefix_wall_s_fused"]
        cell["fused_speedup"] = round(ratio, 3)
        cells.append(cell)
        rows_out.append(f"prefix_fused_speedup_N={n},{ratio:.2f},"
                        f"claim=fused probe->evaluate fast path beats the "
                        f"staged aligned-pack prefix end to end")
    # record the trajectory BEFORE the gates: a regression run is
    # exactly the one whose numbers the artifact must preserve (the CI
    # upload step runs with if: always())
    path = _append_artifact(cells)
    rows_out.append(f"prefix_fusion_artifact,1,{path}")
    for n in sorted(FLEETS):
        # the last timed rounds of both variants selected the same fleet
        if not (masks[n]["fused"] == masks[n]["unfused"]).all():
            raise RuntimeError(
                f"N={n}: fused and unfused selection masks diverge in the "
                f"bench — the fast path is not selection-preserving")
    n256 = next(c for c in cells if c["n_clients"] == 256)
    if n256["fused_speedup"] < MIN_RATIO_N256:
        raise RuntimeError(
            f"fused selection prefix speedup at N=256 is "
            f"{n256['fused_speedup']:.2f}x — under the {MIN_RATIO_N256}x "
            f"acceptance floor")
    return rows_out
