"""Roofline table from the dry-run JSONL artifacts (deliverable g)."""
from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load_rows(path: str) -> List[Dict]:
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return rows


def bench_roofline_table() -> List[str]:
    out = []
    for fname, tag in (("dryrun_optimized.jsonl", "16x16"),
                       ("dryrun_optimized_multipod.jsonl", "2x16x16")):
        rows = load_rows(os.path.join(RESULTS, fname))
        ok = [r for r in rows if r.get("ok")]
        fail = [r for r in rows if not r.get("ok")]
        for r in ok:
            rl = r["roofline"]
            out.append(
                f"roofline_{tag}_{r['arch']}_{r['shape']},"
                f"{rl['bound_s']:.3f},"
                f"dom={rl['dominant']};c={rl['compute_s']:.3f};"
                f"m={rl['memory_s']:.3f};n={rl['collective_s']:.3f};"
                f"useful={r['useful_compute_ratio']:.2f};"
                f"peakGiB={r['memory']['peak_bytes']/2**30:.2f}")
        out.append(f"roofline_{tag}_summary,{len(ok)},"
                   f"ok;{len(fail)} failed")
    return out
