"""Round-engine throughput: batched vmapped engine vs per-client loop.

ISSUE 1 acceptance: the batched engine must be >= 2x faster per round
than the reference loop engine at >= 20 clients on CPU.  The profile is
the motivating regime — a Table-3-shaped fleet scaled to ~100 vehicles
(12 data-rich, the rest data-poor) where the per-round Eq. 7 probe of
every participant dominates.  Both engines get two warm-up rounds (jit
compile excluded — steady state is what Table-3-scale sweeps pay for),
then are timed over ``TIMED_ROUNDS``.

Fairness note: both engines run the SAME semantics over the same
uniform-capacity stacked tensors (required for parity), including the
PR-1 XLA:CPU fixes (reshape pool, loop unrolling, matmul shuffle) — the
loop baseline here is the optimized reference, not the seed.  Uniform
capacity does cost the loop's few small-client survivors some masked
steps the seed's two-cap grouping avoided (~1-2s of its ~21s round);
per-capacity cohort groups are an open ROADMAP item.
"""
from __future__ import annotations

import time
from typing import List

from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation

N_CLIENTS = 96
WARMUP_ROUNDS = 2
TIMED_ROUNDS = 3


def _cfg(engine: str) -> FLSimConfig:
    part = PartitionConfig(n_clients=N_CLIENTS, big_clients=12,
                           big_quantity=200, small_quantity=45,
                           classes_per_client=9)
    return FLSimConfig(scheme="dcs", engine=engine, local_epochs=1,
                       probe_samples=200, samples_per_class=800,
                       partition=part,
                       mobility=MobilityConfig(n_vehicles=N_CLIENTS, seed=0),
                       seed=0)


def bench_engine_throughput() -> List[str]:
    rows = []
    per_round = {}
    for engine in ("loop", "batched"):
        sim = FLSimulation(_cfg(engine))
        sim.warmup()                       # compile cohort buckets up front
        for r in range(WARMUP_ROUNDS):
            sim.run_round(r)
        t0 = time.perf_counter()
        for r in range(WARMUP_ROUNDS, WARMUP_ROUNDS + TIMED_ROUNDS):
            sim.run_round(r)
        dt = (time.perf_counter() - t0) / TIMED_ROUNDS
        per_round[engine] = dt
        rows.append(f"engine_{engine}_round_s,{dt:.3f},"
                    f"n_clients={N_CLIENTS};timed_rounds={TIMED_ROUNDS}")
    speedup = per_round["loop"] / max(per_round["batched"], 1e-9)
    rows.append(f"engine_batched_speedup,{speedup:.2f},"
                f"claim=batched >=2x at >=20 clients")
    return rows
