"""Round-engine throughput: grouped vmapped engine vs the single-stack
batched engine vs the per-client loop.

Two claims are measured:

- ISSUE 1 (updated): the batched engine is faster per round than the
  reference loop engine at >= 20 clients on CPU.  (PR 1 measured >= 2x
  against a loop that padded every client to the max capacity; the loop
  baseline now also trains at per-group caps, so the gap is smaller —
  the honest comparison.)
- ISSUE 2: on a quantity-skewed Table-3-shaped profile, the
  capacity-grouped engine beats the single uniform-capacity stack
  (``uniform_capacity=True``), because small-capacity cohort members
  train their own few steps per epoch instead of the 4500-sample group's
  mostly-masked step count.

Default profile: a Table-3-shaped fleet scaled to ~100 vehicles (12
data-rich, the rest data-poor).  ``REPRO_BENCH_FULL=1`` switches to the
true Table-3 profile (30 vehicles, 12x4500 + 18x45) and drops the loop
engine (untimeable on CPU at cap 4500).  Every engine gets warm-up
rounds (jit compile excluded — steady state is what Table-3-scale sweeps
pay for), then is timed over the remaining rounds.

Fairness note: all engines run the SAME semantics (required for parity),
including the PR-1 XLA:CPU fixes (reshape pool, loop unrolling, matmul
shuffle).  The loop baseline trains each client at its capacity group's
cap, like the grouped engine — the uniform-stack engine is the one
paying the padding bill.
"""
from __future__ import annotations

import os
import time
from typing import List

from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.fl.client import _SCAN_UNROLL, local_train_batch

FULL = os.environ.get("REPRO_BENCH_FULL", "") == "1"

if FULL:                       # true Table 3: 12 x 4500 + 18 x 45
    N_CLIENTS = 30
    PART = dict(big_clients=12, big_quantity=4500, small_quantity=45)
    SAMPLES_PER_CLASS = 7000   # no-dup partition demand is ~5580/class
                               # after the train/test split; keep real
                               # headroom so a seed change can't raise
    PROBE = 256
    N_CENTRAL = 6
    WARMUP_ROUNDS, TIMED_ROUNDS = 1, 2
    ENGINES = ("uniform", "grouped")
else:                          # Table-3-shaped, scaled to CI budget
    N_CLIENTS = 96
    PART = dict(big_clients=12, big_quantity=200, small_quantity=45)
    SAMPLES_PER_CLASS = 800
    PROBE = 200
    N_CENTRAL = 10
    WARMUP_ROUNDS, TIMED_ROUNDS = 2, 3
    ENGINES = ("loop", "uniform", "grouped")

# benchmark label -> (RunConfig.engine, uniform_capacity)
_VARIANTS = {"loop": ("loop", False),
             "uniform": ("batched", True),
             "grouped": ("batched", False)}


def _sim(variant: str) -> FLSimulation:
    engine, uniform = _VARIANTS[variant]
    part = PartitionConfig(n_clients=N_CLIENTS, classes_per_client=9,
                           **PART)
    # scheme="random": the engine comparison wants cohorts whose big/small
    # mix mirrors the fleet (18 of 30 Table-3 vehicles are data-poor);
    # eval-ranked schemes bias cohorts towards big clients and turn this
    # into a selection-quality bench.  All variants draw the identical
    # selection sequence, so the comparison stays apples-to-apples.
    cfg = FLSimConfig(scheme="random", local_epochs=1,
                      n_clients_central=N_CENTRAL, probe_samples=PROBE,
                      samples_per_class=SAMPLES_PER_CLASS,
                      uniform_capacity=uniform, partition=part,
                      mobility=MobilityConfig(n_vehicles=N_CLIENTS, seed=0),
                      seed=0)
    return FLSimulation(cfg, run=RunConfig(engine=engine))


def bench_engine_throughput() -> List[str]:
    rows = []
    per_round = {}
    profile = (f"n_clients={N_CLIENTS};big={PART['big_quantity']};"
               f"small={PART['small_quantity']};timed_rounds={TIMED_ROUNDS}")
    for variant in ENGINES:
        sim = _sim(variant)
        # warmup() pre-executes the trainer once per cohort bucket: cheap
        # insurance at the scaled profile, but at cap 4500 each bucket
        # execution costs a full round's train time (the 225-step scan is
        # execution-bound — its compile is seconds), so FULL relies on
        # the warm-up rounds to compile organically.  A timed FULL round
        # that draws an unseen bucket size pays one scan-trainer compile
        # (~1-10% of a round); acceptable against 40+ min of eager
        # warmup executions.
        if not FULL:
            sim.warmup()               # compile cohort buckets up front
        for r in range(WARMUP_ROUNDS):
            sim.run_round(r)
        t0 = time.perf_counter()
        for r in range(WARMUP_ROUNDS, WARMUP_ROUNDS + TIMED_ROUNDS):
            sim.run_round(r)
        dt = (time.perf_counter() - t0) / TIMED_ROUNDS
        per_round[variant] = dt
        rows.append(f"engine_{variant}_round_s,{dt:.3f},{profile}")
    if "loop" in per_round:
        speedup = per_round["loop"] / max(per_round["grouped"], 1e-9)
        rows.append(f"engine_batched_speedup,{speedup:.2f},"
                    f"claim=batched beats the per-client loop (which now "
                    f"also trains at per-group caps)")
    grp = per_round["uniform"] / max(per_round["grouped"], 1e-9)
    rows.append(f"engine_grouped_speedup,{grp:.2f},"
                f"claim=capacity groups beat the uniform max-cap stack")
    return rows


_OVERLAP_CHILD = r"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys, time, json
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.launch.sweep import run_seed_group

overlap = sys.argv[1] == "overlap"
shape = sys.argv[2]                    # single | sweep

def cfg(scheme, classes, dist, seed):
    part = PartitionConfig(n_clients=32, big_clients=4, big_quantity=200,
                           small_quantity=45, classes_per_client=9,
                           seed=seed)
    return FLSimConfig(scheme="random", local_epochs=1,
                       n_clients_central=8, probe_samples=64,
                       samples_per_class=400, partition=part,
                       mobility=MobilityConfig(n_vehicles=32, seed=seed),
                       seed=seed)

rounds = 3
if shape == "single":
    sim = FLSimulation(cfg("random", 9, "uniform", 0))
    sim.warmup()
    sim.run(1, overlap=overlap)                 # compile prefix/metrics
    t0 = time.perf_counter()
    sim.run(rounds, overlap=overlap)
else:
    seeds = [0, 1, 2, 3]
    run_seed_group("random", 9, "uniform", seeds, 1, cfg_fn=cfg,
                   overlap=overlap)             # warm every seed's jits
    t0 = time.perf_counter()
    run_seed_group("random", 9, "uniform", seeds, rounds, cfg_fn=cfg,
                   overlap=overlap)
print(json.dumps({"round_s": (time.perf_counter() - t0) / rounds}))
"""


def bench_round_overlap() -> List[str]:
    """ISSUE 5: the round-ahead scheduler vs the serial driver.

    Same rounds, same math (rows pinned identical in
    tests/test_probe_fuzzy.py) — the overlap driver enqueues round
    r+1's selection prefix right after round r's trainers, before any
    metric reads.  Each (variant, shape) cell runs in its OWN
    subprocess so neither side inherits the other's warm jit caches
    (a same-process comparison confounds compile reuse with overlap).

    Two shapes, both warmed before timing:

    - **single** sim: the dependency chain selection_{r+1} <- agg_r <-
      train_r is inherently serial and XLA:CPU drains one in-order
      execution stream, so a lone simulation can only hide the
      host-side dispatch gaps (~ms) — reported as the honest
      ~break-even baseline.
    - **sweep** cell (4 seeds — the scheduler's actual target): the
      serial driver resolves each seed's metrics/row between training
      dispatches, idling the device once per seed per round; the
      round-ahead driver enqueues all seeds' training and the next
      vmapped selection dispatch before any row resolve, so the device
      queue never drains while the host does per-seed bookkeeping.
      This is the wall-clock overlap claim (selection_{r+1}'s dispatch
      + cross-seed device work hide the per-seed host tails)."""
    import json as _json
    import subprocess as _sp
    import sys as _sys
    from pathlib import Path as _Path

    rows, per = [], {}
    src = str(_Path(__file__).resolve().parent.parent / "src")
    prev = os.environ.get("PYTHONPATH")
    env = {**os.environ,
           "PYTHONPATH": src + (os.pathsep + prev if prev else "")}
    for shape in ("single", "sweep"):
        for label in ("serial", "overlap"):
            proc = _sp.run([_sys.executable, "-c", _OVERLAP_CHILD, label,
                            shape], capture_output=True, text=True,
                           env=env, timeout=900)
            if proc.returncode != 0:
                raise RuntimeError(f"overlap child {shape}/{label} "
                                   f"failed:\n{proc.stderr[-2000:]}")
            got = _json.loads(proc.stdout.strip().splitlines()[-1])
            per[(shape, label)] = got["round_s"]
            rows.append(f"engine_{shape}_{label}_round_s,"
                        f"{got['round_s']:.3f},n_clients=32;warm;"
                        f"round-ahead={label == 'overlap'};"
                        f"{'4 seeds' if shape == 'sweep' else '1 sim'}")
    single = per[("single", "serial")] / per[("single", "overlap")]
    rows.append(f"engine_overlap_single_ratio,{single:.3f},"
                f"one sim on one in-order CPU stream: only host dispatch "
                f"gaps to hide — informational, not gated")
    hidden = per[("sweep", "serial")] - per[("sweep", "overlap")]
    speedup = per[("sweep", "serial")] / per[("sweep", "overlap")]
    rows.append(f"engine_overlap_hidden_s,{hidden:.3f},"
                f"per-round wall hidden in a 4-seed sweep cell: device "
                f"queue stays full through per-seed metric resolves")
    rows.append(f"engine_overlap_speedup,{speedup:.3f},"
                f"claim=round-ahead scheduler hides selection dispatch + "
                f"cross-seed work under the per-seed round tails")
    return rows


def bench_trainer_unroll() -> List[str]:
    """ISSUE 3 satellite: chunk-unrolling the ``lax.scan`` step loop.

    Step counts past ``_UNROLL_LIMIT`` (the Table-3 cap-4500 trainer:
    225 steps/epoch) pay the XLA:CPU while-loop overhead per iteration;
    ``lax.scan(..., unroll=_SCAN_UNROLL)`` amortizes the loop overhead
    over straight-line blocks.  Measured here on a cap-1600 2-client
    cohort (80 steps/epoch — scan path, CI-affordable): before = unroll
    1 (the pre-ISSUE-3 scan), after = the engine default (~1.1x on the
    2-core dev box — the conv-grad body dominates, so the win is real
    but modest).  Math is identical — same steps, same order."""
    import jax
    import jax.numpy as jnp
    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.models.cnn import init_cnn

    c, cap, batch = 2, 1600, 20                 # 80 steps > _UNROLL_LIMIT
    key = jax.random.PRNGKey(0)
    params = init_cnn(key, CNN_CFG)
    images = jax.random.normal(key, (c, cap, 28, 28, 1))
    labels = jnp.zeros((c, cap), jnp.int32)
    n_valid = jnp.full((c,), cap, jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(1), c)

    rows, per_call = [], {}
    profile = f"c={c};cap={cap};steps={cap // batch};epochs=1"
    for label, unroll in (("scan", 1), ("chunked", _SCAN_UNROLL)):
        kw = dict(epochs=1, batch_size=batch, steps_per_epoch=cap // batch,
                  lr=0.05, scan_unroll=unroll)
        out, _ = local_train_batch(params, images, labels, n_valid, keys,
                                   **kw)                  # compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out, _ = local_train_batch(params, images, labels, n_valid, keys,
                                   **kw)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        per_call[label] = dt
        rows.append(f"trainer_{label}_call_s,{dt:.3f},"
                    f"{profile};unroll={unroll}")
    speedup = per_call["scan"] / max(per_call["chunked"], 1e-9)
    rows.append(f"trainer_unroll_speedup,{speedup:.2f},"
                f"claim=chunk-unrolled scan beats the while-loop slow path")
    return rows
