"""End-to-end federated training (the paper's experiment, §6).

Runs the full round engine — broadcast, Eq. 7 probe, fuzzy evaluation,
DCS election, Eq. 1 local SGD on the selected vehicles, deadline filter,
FedAvg aggregation — for several rounds on the synthetic non-iid dataset,
and prints the accuracy trajectory vs the random baseline.

Each round trains ~5 clients x 15-30 local steps, so 10 rounds ≈ several
hundred local SGD steps end-to-end (the paper's kind of workload: the
local model is the 1.66M-param CNN).

  PYTHONPATH=src python examples/fl_training.py [rounds]
"""
import sys
import time

import numpy as np

from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation

ROUNDS = int(sys.argv[1]) if len(sys.argv) > 1 else 8


def run(scheme: str) -> list:
    cfg = FLSimConfig(
        scheme=scheme,
        local_epochs=1,
        samples_per_class=520,
        probe_samples=128,
        partition=PartitionConfig(big_quantity=200, small_quantity=45,
                                  classes_per_client=9),
        mobility=MobilityConfig(seed=0),
        seed=0,
    )
    sim = FLSimulation(cfg)
    hist = []
    for r in range(ROUNDS):
        t0 = time.time()
        row = sim.run_round(r)
        hist.append(row)
        print(f"  [{scheme}] round {r}: acc={row['accuracy']:.3f} "
              f"selected={row['n_selected']} aggregated={row['n_aggregated']}"
              f" stragglers={row['n_straggler']} ({time.time()-t0:.0f}s)",
              flush=True)
    return hist


if __name__ == "__main__":
    print("=== DCS (the paper's scheme) ===")
    h_dcs = run("dcs")
    print("=== random (CCS baseline) ===")
    h_rnd = run("random")
    a1 = max(h["accuracy"] for h in h_dcs)
    a2 = max(h["accuracy"] for h in h_rnd)
    print(f"\nbest accuracy: DCS={a1:.3f} random={a2:.3f} "
          f"(paper: DCS outperforms random after enough rounds)")
    s1 = np.mean([h["n_selected"] for h in h_dcs])
    print(f"DCS avg selected clients: {s1:.2f} (paper: ~5.15)")
