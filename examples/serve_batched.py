"""Batched serving demo: prefill + KV/state-cache decode on reduced
variants of three assigned architectures (dense, attention-free RNN,
hybrid) — the serving substrate the FL server uses to evaluate uploaded
models, and the path the decode-shape dry-runs lower at production scale.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, scaled_down
from repro.models import transformer as tfm
from repro.serve.engine import generate

BATCH, PROMPT, NEW = 4, 48, 24

for arch in ("gemma-2b", "rwkv6-3b", "jamba-v0.1-52b"):
    cfg = scaled_down(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (BATCH, PROMPT), 0,
                                          cfg.vocab_size)}
    t0 = time.time()
    toks, info = generate(cfg, params, batch, NEW, temperature=0.8, key=key)
    toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"{arch:>16s} ({cfg.family:6s}): {BATCH}x{NEW} tokens in {dt:5.1f}s"
          f" ({BATCH*NEW/dt:6.1f} tok/s)  sample: {toks[0][:10].tolist()}")
