"""Quickstart: the paper's core pipeline in 60 lines.

30 vehicles on a 1 km road -> fuzzy multi-objective evaluation (local)
-> distributed neighbour election (DSRC, top-2 per 200 m) -> compare with
centralized fuzzy selection and the Eq. 5 communication overhead.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.overhead import (GBoardParams, crossing_interval_s,
                                 state_maintenance_bytes)
from repro.core.selection import ccs_fuzzy_select, dcs_select
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig

rng = np.random.default_rng(0)
N = 30

# --- vehicle state (locally observable; nothing goes to a server) ---------
mob = FreewayMobility(MobilityConfig(n_vehicles=N, seed=0))
net = CellularNetwork(NetworkConfig(seed=0))
pos = mob.positions(t_s=0.0)

sample_quantity = np.where(np.arange(N) < 12, 4500, 45)        # Table 3
throughput = net.predicted_throughput(pos)                     # CWND avg
capability = rng.uniform(0.25, 1.0, N)                         # 1/C_i
loss_probe = rng.uniform(0.5, 3.0, N)                          # Eq. 7

features = jnp.asarray(np.stack([
    sample_quantity / sample_quantity.max(),
    throughput / throughput.max(),
    capability / capability.max(),
    loss_probe / loss_probe.max(),
], axis=1), jnp.float32)

# --- fuzzy evaluation (Mamdani, 81 rules, COG) -----------------------------
evaluator = FuzzyEvaluator()
evals = evaluator.evaluate(features)
print("evaluations (0-100):", np.round(np.asarray(evals), 1))
print("levels:", np.asarray(evaluator.level_of(evals)))

# --- distributed client selection (paper Alg. 1) ---------------------------
mask_dcs = dcs_select(jnp.asarray(pos), evals, comm_range=200.0, top_m=2,
                      e_tau=30.0)
sel_dcs = np.where(np.asarray(mask_dcs))[0]
print(f"\nDCS selected {len(sel_dcs)} clients (paper avg ~5.15): {sel_dcs}")

# --- centralized fuzzy selection for comparison ----------------------------
mask_ccs = ccs_fuzzy_select(evals, 5)
sel_ccs = np.where(np.asarray(mask_ccs))[0]
overlap = set(sel_dcs) & set(sel_ccs)
print(f"CCS-fuzzy top-5: {sel_ccs}; overlap with DCS: {sorted(overlap)}")

# --- the Eq. 5 overhead the DCS scheme eliminates --------------------------
p = GBoardParams()
c = state_maintenance_bytes(p.n_participants, p.state_bytes_cfl,
                            p.round_period_s, 1.0)
x = crossing_interval_s(p.n_participants, p.state_bytes_cfl,
                        p.round_period_s, p.clients_per_round, p.model_bytes)
print(f"\nEq.5 @ GBoard scale: state upkeep {c/1e9:.1f} GB/round at tau=1s "
      f"(model uploads: 0.42 GB); curves cross at tau={x:.0f}s")
print("DCS sends zero state to the server: selection is neighbour-local.")
