"""rwkv6-3b — Finch, attention-free RNN with data-dependent decay.

[arXiv:2404.05892] "Eagle and Finch: RWKV with Matrix-Valued States and
Dynamic Recurrence".  32L, d_model=2560, d_ff=8960, vocab=65536,
head_size=64 (=> 40 WKV heads).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=8960,
    vocab_size=65536,
    hidden_act="relu_sq",         # rwkv channel-mix uses relu^2
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    tie_embeddings=False,
    citation="arXiv:2404.05892",
)
