"""whisper-medium — encoder-decoder speech backbone (conv/mel frontend STUB).

[arXiv:2212.04356] "Robust Speech Recognition via Large-Scale Weak
Supervision".  24L decoder (+24L encoder), d_model=1024, 16 heads (MHA:
kv=16), d_ff=4096, vocab=51865.  ``input_specs`` feeds precomputed frame
embeddings (B, 1500, d_model) — the mel+conv frontend is the one allowed stub.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    hidden_act="gelu",
    norm="layernorm",
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    tie_embeddings=True,
    sliding_window=8192,          # backbone-generalised long decode (ours)
    citation="arXiv:2212.04356",
)
