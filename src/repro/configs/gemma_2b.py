"""gemma-2b — Google Gemma 2B: GeGLU, oversized head_dim=256, MQA.

[arXiv:2403.08295] "Gemma: Open Models Based on Gemini Research and
Technology".  18L, d_model=2048, 8 heads, MQA kv=1, head_dim=256,
d_ff=16384 (GeGLU), vocab=256000, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    hidden_act="geglu",
    tie_embeddings=True,
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    scale_embed=True,
    citation="arXiv:2403.08295",
)
