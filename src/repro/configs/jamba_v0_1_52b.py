"""jamba-v0.1-52b — AI21 Jamba: Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] "Jamba: A Hybrid Transformer-Mamba Language Model".
32L (4 Jamba blocks x 8 layers; 1 attention layer per 8, offset 4 in the
released model), d_model=4096, 32 heads, GQA kv=8, d_ff=14336, vocab=65536,
MoE with 16 experts top-2 on every other layer (offset 1).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    hidden_act="silu",
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    moe_layer_offset=1,
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    tie_embeddings=False,
    citation="arXiv:2403.19887",
)
