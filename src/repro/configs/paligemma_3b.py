"""paligemma-3b — SigLIP + Gemma VLM (vision tower STUB).

[arXiv:2407.07726] "PaliGemma: A versatile 3B VLM for transfer".  Language
backbone = gemma-2b: 18L, d_model=2048, 8 heads, MQA kv=1, head_dim=256,
GeGLU d_ff=16384, vocab=257216 (extended with <locNNNN>/<segNNN>).
``input_specs`` feeds 256 precomputed SigLIP patch embeddings per image;
prefix-LM masking over the image+prompt prefix.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    hidden_act="geglu",
    num_prefix_tokens=256,
    tie_embeddings=True,
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    scale_embed=True,
    citation="arXiv:2407.07726",
)
