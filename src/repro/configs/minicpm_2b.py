"""minicpm-2b — MiniCPM, llama-like with WSD schedule + depth-scaled residuals.

[arXiv:2404.06395] "MiniCPM: Unveiling the Potential of Small Language Models
with Scalable Training Strategies".  40L, d_model=2304, 36 heads, kv=36
(MHA), d_ff=5760, vocab=122753, residual scaling 1.4/sqrt(40), WSD LR.
"""
import math

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    hidden_act="silu",
    tie_embeddings=True,
    residual_scale=1.4 / math.sqrt(40),
    lr_schedule="wsd",
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    citation="arXiv:2404.06395",
)
