"""granite-8b — IBM Granite Code 8B, llama-arch dense decoder.

[arXiv:2405.04324] "Granite Code Models".  36L, d_model=4096, 32 heads,
GQA kv=8, d_ff=14336, vocab=49152.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    hidden_act="silu",
    tie_embeddings=True,          # granite-8b-code ties embeddings
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    citation="arXiv:2405.04324",
)
