"""yi-6b — 01.AI Yi, llama-arch dense decoder with aggressive GQA.

[arXiv:2403.04652] "Yi: Open Foundation Models by 01.AI".  32L,
d_model=4096, 32 heads, GQA kv=4, d_ff=11008, vocab=64000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    hidden_act="silu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    citation="arXiv:2403.04652",
)
