"""Architecture + input-shape configuration dataclasses.

Every assigned architecture gets one module in this package defining
``CONFIG: ArchConfig`` with the exact public-literature hyperparameters
(source cited in ``citation``).  ``repro.configs.get_arch(name)`` resolves the
``--arch <id>`` CLI ids (which may contain dots/dashes) to those modules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    """A single transformer/SSM/hybrid architecture.

    The decoder "backbone" view: for [audio]/[vlm] archs the modality
    frontend is a stub and ``encoder_seq``/``num_prefix_tokens`` describe the
    precomputed embeddings the backbone consumes.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free (rwkv)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    citation: str

    # --- layer flavour -----------------------------------------------------
    hidden_act: str = "silu"         # silu | geglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    logit_softcap: float = 0.0

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden width
    moe_layer_period: int = 1        # every `period`-th layer is MoE
    moe_layer_offset: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba) / RWKV ------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64        # rank of the data-dependent decay LoRA

    # --- hybrid (jamba): one attention layer per `attn_layer_period` -------
    attn_layer_period: int = 0
    attn_layer_offset: int = 0

    # --- encoder-decoder (whisper backbone) --------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings length

    # --- VLM (paligemma): prefix patch embeddings --------------------------
    num_prefix_tokens: int = 0

    # --- long-context decode strategy --------------------------------------
    sliding_window: int = 0          # >0: sliding-window attention available

    # --- attention flavour --------------------------------------------------
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    scale_embed: bool = False        # gemma-style sqrt(d_model) embed scaling

    # --- training ----------------------------------------------------------
    residual_scale: float = 1.0      # minicpm depth-scaled residuals
    lr_schedule: str = "cosine"      # cosine | wsd

    # -----------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.num_heads == 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'mamba' body for decoder layer i (hybrid interleave)."""
        if self.family != "hybrid" or self.attn_layer_period == 0:
            return "mamba" if self.name.startswith("rwkv") else "attn"
        return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                else "mamba")

    def layer_is_moe(self, i: int) -> bool:
        if not self.is_moe:
            return False
        return i % self.moe_layer_period == self.moe_layer_offset

    def num_params(self) -> int:
        """Analytic parameter count (embedding + per-layer), for roofline."""
        d, v = self.d_model, self.vocab_size
        n = v * d                                    # embedding
        if not self.tie_embeddings:
            n += v * d
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if self.name.startswith("rwkv"):
                h = self.d_model
                n += 6 * d * d                       # r,k,v,g,o + decay-ish
                n += 2 * self.rwkv_decay_lora * d * 5
                n += d * self.d_ff + self.d_ff * d   # channel mix
                n += 4 * d
                continue
            if kind == "attn":
                n += d * self.num_heads * self.head_dim          # q
                n += 2 * d * self.num_kv_heads * self.head_dim   # k,v
                n += self.num_heads * self.head_dim * d          # o
            else:  # mamba
                di = self.ssm_expand * d
                n += d * 2 * di + di * d                          # in/out proj
                n += di * self.ssm_conv_width
                n += di * (2 * self.ssm_state_dim + di // 16 * 2)  # x_proj+dt
            if self.layer_is_moe(i):
                ff = self.moe_d_ff or self.d_ff
                n += self.num_experts * 3 * d * ff + d * self.num_experts
            else:
                mult = 3 if self.hidden_act in ("silu", "geglu") else 2
                n += mult * d * self.d_ff
            n += 2 * d                                # norms
        return n

    def num_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts only routed experts."""
        if not self.is_moe:
            return self.num_params()
        full = self.num_params()
        ff = self.moe_d_ff or self.d_ff
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.num_layers))
        dead = (self.num_experts - self.experts_per_token) * 3 * self.d_model * ff
        return full - n_moe_layers * dead


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    grad_accum: int = 1              # train only: microbatch count


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train", grad_accum=8)
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def scaled_down(cfg: ArchConfig, *, layers: int = 2, d_model: int = 256,
                experts: int = 4) -> ArchConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    heads = 0 if cfg.num_heads == 0 else max(2, min(cfg.num_heads, 4))
    kv = 0 if cfg.num_kv_heads == 0 else max(1, min(cfg.num_kv_heads, heads))
    if heads and cfg.num_heads and cfg.num_kv_heads == cfg.num_heads:
        kv = heads                                    # keep MHA archs MHA
    head_dim = max(16, d_model // max(heads, 1)) if heads else 0
    if cfg.head_dim > cfg.d_model // max(cfg.num_heads, 1):
        head_dim = 2 * d_model // max(heads, 1)       # gemma-style oversized
    upd = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=d_model * 4,
        vocab_size=512,
        rwkv_decay_lora=16,
        encoder_layers=min(cfg.encoder_layers, layers),
        encoder_seq=min(cfg.encoder_seq, 64),
        num_prefix_tokens=min(cfg.num_prefix_tokens, 16),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
    )
    if cfg.is_moe:
        upd.update(num_experts=min(experts, cfg.num_experts),
                   experts_per_token=min(cfg.experts_per_token, 2),
                   moe_d_ff=d_model * 2)
    if cfg.family == "hybrid":
        upd.update(attn_layer_period=2, attn_layer_offset=0)
    return dataclasses.replace(cfg, **upd)
