"""phi3.5-moe-42b-a6.6b — Microsoft Phi-3.5-MoE, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct].  32L, d_model=4096, 32 heads, GQA kv=8,
per-expert d_ff=6400, vocab=32064, MoE on every layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    hidden_act="silu",
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=6400,
    moe_layer_period=1,
    tie_embeddings=False,
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
)
