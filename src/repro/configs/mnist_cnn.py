"""The paper's local model: 7-layer CNN for 28x28x1 images, ~1.66M params.

Paper §6.1: "two layers of convolution layer, one layer of flattened layer,
two layers of max pooling layer, and two layers of the fully connected
layer ... about 1.66 million [trainable variables] ... 5.2 Mbytes".

Topology (chosen to hit 1.66M):
  conv 5x5x1->32, maxpool 2x2, conv 5x5x32->64, maxpool 2x2, flatten,
  fc 3136->512, fc 512->10.
Params = 832 + 51_264 + 1_606_144 + 5_130 = 1_663_370  (~1.66M, 6.65MB fp32;
the paper's 5.2MB suggests mixed precision on disk — noted, not replicated).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class CNNConfig:
    name: str = "mnist-cnn"
    image_size: int = 28
    channels: int = 1
    conv_channels: tuple = (32, 64)
    kernel_size: int = 5
    fc_width: int = 512
    num_classes: int = 10
    citation: str = "paper §6.1 (MNIST CNN, ~1.66M params)"


CONFIG = CNNConfig()
