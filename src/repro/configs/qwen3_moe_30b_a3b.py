"""qwen3-moe-30b-a3b — Qwen3 fine-grained MoE: 128 experts top-8.

[hf:Qwen/Qwen3-30B-A3B].  48L, d_model=2048, 32 heads (head_dim=128), GQA
kv=4, per-expert d_ff=768, vocab=151936, MoE on every layer, QK-norm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    hidden_act="silu",
    rope_theta=1_000_000.0,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    moe_layer_period=1,
    tie_embeddings=False,
    sliding_window=8192,          # long_500k sub-quadratic variant (ours)
    qk_norm=True,
    citation="hf:Qwen/Qwen3-30B-A3B",
)
