"""Config registry: resolves ``--arch <id>`` ids to ArchConfig instances."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K,
                                scaled_down)

# CLI id -> module name (ids may contain characters invalid in module names).
_ARCH_MODULES: Dict[str, str] = {
    "rwkv6-3b": "rwkv6_3b",
    "granite-8b": "granite_8b",
    "whisper-medium": "whisper_medium",
    "yi-6b": "yi_6b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "paligemma-3b": "paligemma_3b",
    "gemma-2b": "gemma_2b",
    "minicpm-2b": "minicpm_2b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {list(SHAPES)}")
    return SHAPES[name]


def all_archs() -> Dict[str, ArchConfig]:
    return {n: get_arch(n) for n in ARCH_IDS}


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_arch", "get_shape", "all_archs", "scaled_down",
]
