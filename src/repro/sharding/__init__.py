from repro.sharding.api import (DEFAULT_RULES, constrain, logical_sharding,
                                resolve_pspec)
from repro.sharding.rules import (batch_shardings, cache_shardings,
                                  param_shardings, replicated)

__all__ = [
    "DEFAULT_RULES", "constrain", "logical_sharding", "resolve_pspec",
    "batch_shardings", "cache_shardings", "param_shardings", "replicated",
]
