"""Logical-axis sharding constraints.

Model code annotates intermediates with *logical* axis names
(``constrain(x, ("batch", None, "embed"))``).  The launcher activates a
mesh + logical->physical rules; without an active context (CPU unit tests)
``constrain`` is a no-op.  Axes whose dimension is not divisible by the
assigned mesh axes are dropped (replicated) — uneven sharding is never
requested — and every drop is logged with the axis name so replication is
never silent.  Callers that *require* a partition (the in-round client
axis of ``fl/pipeline.py``) pass ``require=`` to ``resolve_pspec`` and
get a ``ValueError`` instead of a replicated fallback.
"""
from __future__ import annotations

import contextlib
import logging
import threading
from typing import Collection, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisAssign = Union[None, str, Tuple[str, ...]]

# the mesh axis the FL round pipeline partitions its client dimension
# over — single source of truth for launch/mesh.py and fl/pipeline.py
CLIENT_AXIS = "clients"

logger = logging.getLogger(__name__)

_state = threading.local()


def _current() -> Tuple[Optional[Mesh], Dict[str, AxisAssign]]:
    return (getattr(_state, "mesh", None), getattr(_state, "rules", {}))


def current_mesh() -> Optional[Mesh]:
    """The mesh activated by ``logical_sharding`` (None outside it)."""
    return _current()[0]


@contextlib.contextmanager
def logical_sharding(mesh: Mesh, rules: Dict[str, AxisAssign]):
    """Activate logical->physical rules for ``constrain`` calls."""
    prev = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def mesh_axis_size(mesh: Optional[Mesh], axis: str) -> int:
    """The extent of one named mesh axis — 1 when the mesh is None or
    the axis is absent.  Single source of truth for "how many shards
    does this logical axis split into" questions (``fl/pipeline.py``'s
    client-axis partition factor, the launchers' mesh probing)."""
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(axis, 1))


def mesh_is_multihost(mesh: Optional[Mesh]) -> bool:
    """True iff ``mesh`` spans more than one jax process — the sharded
    FL pipeline then keeps its host-consumed outputs replicated and its
    per-client statics addressable-shard-only."""
    if mesh is None:
        return False
    return len({d.process_index for d in mesh.devices.flat}) > 1


def _axis_size(mesh: Mesh, assign: AxisAssign) -> int:
    if assign is None:
        return 1
    names = (assign,) if isinstance(assign, str) else assign
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def resolve_pspec(mesh: Mesh, rules: Dict[str, AxisAssign],
                  logical: Sequence[Optional[str]],
                  shape: Sequence[int],
                  require: Collection[str] = ()) -> P:
    """Logical spec -> PartitionSpec, dropping non-divisible axes.

    Every dropped (replicated) axis is logged: ``debug`` when the rule
    resolves to no live mesh axis (size-1 or absent — replication is the
    intended outcome), ``warning`` when the dimension is simply not
    divisible by the assigned mesh extent (the surprising case that used
    to be silent).  Logical axes listed in ``require`` raise a
    ``ValueError`` instead of falling back to replication — the client
    partition of the sharded round pipeline must never quietly collapse
    onto one device."""
    out = []
    used = set()
    for dim, name in zip(shape, logical):
        assign = rules.get(name) if name else None
        if assign is None:
            if name and name in require:
                raise ValueError(
                    f"logical axis {name!r} (dim {dim}) is required to be "
                    f"sharded but has no rule mapping it to a mesh axis")
            out.append(None)
            continue
        names = (assign,) if isinstance(assign, str) else tuple(assign)
        names = tuple(a for a in names if a in mesh.shape and a not in used)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if not names or size == 1:
            if name in require:
                raise ValueError(
                    f"logical axis {name!r} (dim {dim}) is required to be "
                    f"sharded but its assigned mesh axes {assign!r} are "
                    f"absent or size 1 on mesh {dict(mesh.shape)}")
            logger.debug("replicating logical axis %r (dim %d): mesh "
                         "axes %r absent or size 1", name, dim, assign)
            out.append(None)
            continue
        if dim % size != 0:
            if name in require:
                raise ValueError(
                    f"logical axis {name!r} has dim {dim}, not divisible "
                    f"by mesh extent {size} of {names!r} — pad the axis "
                    f"to a mesh multiple instead of replicating")
            logger.warning("replicating logical axis %r: dim %d not "
                           "divisible by mesh extent %d of %r",
                           name, dim, size, names)
            out.append(None)
            continue
        used.update(names)
        out.append(names[0] if len(names) == 1 else names)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    mesh, rules = _current()
    if mesh is None or not rules:
        return x
    spec = resolve_pspec(mesh, rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_kv(x: jax.Array) -> jax.Array:
    """Constraint for prefill K/V tensors (B, S, Hkv, Dh): batch over the
    batch axes, then heads over 'model' if divisible, else slots over
    'model' — mirrors rules.cache_shardings so the scan-built cache keeps
    a device-sized sharding instead of whatever GSPMD back-propagates."""
    mesh, rules = _current()
    if mesh is None:
        return x
    spec: list = [None, None, None, None]
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape[a]
    if baxes and bsz > 1 and x.shape[0] % bsz == 0:
        spec[0] = baxes if len(baxes) > 1 else baxes[0]
    elif "data" in mesh.shape and x.shape[1] % mesh.shape["data"] == 0:
        spec[1] = "data"
    if "model" in mesh.shape and mesh.shape["model"] > 1:
        m = mesh.shape["model"]
        if x.shape[2] % m == 0:
            spec[2] = "model"
        elif spec[1] is None and x.shape[1] % m == 0:
            spec[1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def sweep_devices() -> Sequence[jax.Device]:
    """Devices available for embarrassingly-parallel sweep cells (whole
    (seed, scheme, partition) simulations — ``repro.launch.sweep``).

    Inside an active ``logical_sharding`` context the mesh's device list
    is the placement domain; otherwise every local device is.  A
    single-CPU host returns one device — the sweep harness falls back to
    serial execution in that case.

    A mesh with a live ``clients`` axis partitions *within* each round
    (the mesh-sharded selection prefix / grouped trainer), so the whole
    mesh is ONE placement domain: every sweep cell uses all of its
    devices, and round-robin placement over the individual devices would
    fight the in-round partition.  Such a mesh returns a single entry."""
    mesh = current_mesh()
    if mesh is not None:
        if dict(mesh.shape).get(CLIENT_AXIS, 1) > 1:
            # single entry = this process's first *addressable* mesh
            # device: on a multi-process mesh a remote device cannot
            # receive host transfers, so it is unusable as a
            # jax.default_device placement target
            pidx = jax.process_index()
            local = [d for d in mesh.devices.flat
                     if d.process_index == pidx]
            return [local[0] if local else mesh.devices.flat[0]]
        return list(mesh.devices.flat)
    return list(jax.devices())


# Default logical rules for the production meshes.
DEFAULT_RULES: Dict[str, AxisAssign] = {
    "batch": ("pod", "data"),
    "embed": None,          # residual stream replicated across 'model'
    "heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "capacity": "data",     # MoE dispatch-buffer capacity dim
    "tokens": ("pod", "data"),
    "kv_seq": "data",
    CLIENT_AXIS: CLIENT_AXIS,   # FL in-round client axis (launch --mesh)
}
