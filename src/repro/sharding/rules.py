"""Parameter / batch / cache sharding rules over the production mesh.

Generic policy (per-arch overrides possible via ``overrides``):

- 1-D params: replicated.
- 2-D params (d_in, d_out): d_out -> 'model' if divisible; d_in -> 'data'
  if divisible (ZeRO-style weight sharding; GSPMD all-gathers on use).
- 3-D expert-stacked params (E, d_in, d_out): E -> 'model' (expert
  parallelism), d_out -> 'data'.
- batches: leading (batch) dim over ('pod','data').
- KV caches: batch -> 'data' when divisible, else cache sequence/slots ->
  'data' (long-context, batch=1); kv-heads -> 'model' when divisible.
- SSM/RWKV states: batch -> 'data' if divisible; channel dim -> 'model'.

Everything returns NamedSharding trees suitable for jit in_shardings.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _div(dim: int, mesh: Mesh, axis) -> bool:
    names = (axis,) if isinstance(axis, str) else axis
    size = 1
    for a in names:
        if a not in mesh.shape:
            return False
        size *= mesh.shape[a]
    return size > 1 and dim % size == 0


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_pspec(path: Tuple[str, ...], shape: Tuple[int, ...],
                mesh: Mesh, cfg: Optional[ArchConfig] = None) -> P:
    """Sharding for one parameter leaf.  ``path`` is the flattened key path
    (strings), ``shape`` excludes any leading stacked-layer axes, which the
    caller must strip — see ``param_shardings``."""
    if len(shape) <= 1:
        return P()
    spec: list = [None] * len(shape)
    if path and path[-1] == "embed":
        # vocab-parallel embedding: gather lowers to mask+all-reduce
        if _div(shape[0], mesh, "model"):
            spec[0] = "model"
        elif _div(shape[1], mesh, "data"):
            spec[1] = "data"
        return P(*spec)
    is_expert = any(k in ("wi", "wg", "wo") for k in path) and len(shape) == 3
    if is_expert:
        # (E, d_in, d_out): experts over 'model', dim1 ZeRO-sharded over
        # 'data' (matches the shard_map EP path's in_specs + all-gather)
        if _div(shape[0], mesh, "model"):
            spec[0] = "model"
        if _div(shape[1], mesh, "data"):
            spec[1] = "data"
        return P(*spec)
    # down-projections: contraction dim (dim0) is produced model-sharded
    # (MLP hidden / attention heads / mamba inner) -> row-parallel: shard
    # dim0 over 'model' so the matmul is local + one all-reduce of the
    # (tokens, d_model) output, instead of all-gathering the big hidden.
    if path and path[-1] in ("wo", "out_proj", "x_proj", "cv"):
        if _div(shape[0], mesh, "model"):
            spec[0] = "model"
        if _div(shape[-1], mesh, "data"):
            spec[-1] = "data"
        return P(*spec)
    # generic matmul weight: column-parallel + ZeRO on dim0
    if _div(shape[-1], mesh, "model"):
        spec[-1] = "model"
    if _div(shape[0], mesh, "data") and len(shape) >= 2:
        spec[0] = "data"
    return P(*spec)


def _stacked_depth(path: Tuple[str, ...]) -> int:
    """How many leading axes are layer/group stacking (not weight dims)."""
    return 1 if "blocks" in path or "layers" in path else 0


def _path_strs(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def param_shardings(params_shape: Any, mesh: Mesh,
                    cfg: Optional[ArchConfig] = None) -> Any:
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree."""
    def one(path, leaf):
        p = _path_strs(path)
        shape = tuple(leaf.shape)
        skip = _stacked_depth(p)
        core = shape[skip:] if skip and len(shape) > skip else shape
        spec = param_pspec(p, core, mesh, cfg)
        full = P(*([None] * (len(shape) - len(core)) + list(spec)))
        return NamedSharding(mesh, full)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(batch_shape: Any, mesh: Mesh) -> Any:
    ba = _batch_axes(mesh)

    def one(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        if _div(shape[0], mesh, ba):
            spec[0] = ba if len(ba) > 1 else ba[0]
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: Any, mesh: Mesh,
                    cfg: Optional[ArchConfig] = None) -> Any:
    """Decode-cache sharding.  Leaves have a leading stacked-layer axis."""
    def one(path, leaf):
        p = _path_strs(path)
        shape = tuple(leaf.shape)
        spec: list = [None] * len(shape)
        name = p[-1] if p else ""
        if name in ("pos", "idx") or len(shape) <= 1:
            return NamedSharding(mesh, P())
        # layer-stacked leaves: dim0 = layer/group axis
        b_dim = 1 if len(shape) >= 2 else None
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, T, Hkv, Dh)
            if len(shape) == 5:
                if _div(shape[1], mesh, "data"):
                    spec[1] = "data"
                elif _div(shape[2], mesh, "data"):
                    spec[2] = "data"          # long-context: shard slots
                if _div(shape[3], mesh, "model"):
                    spec[3] = "model"         # kv heads
                elif spec[2] is None and _div(shape[2], mesh, "model"):
                    spec[2] = "model"         # fall back: shard slots
        elif name in ("h", "S", "conv"):       # SSM/RWKV states
            if len(shape) >= 3 and _div(shape[1], mesh, "data"):
                spec[1] = "data"
            # channel dim -> model
            for d in range(2, len(shape)):
                if _div(shape[d], mesh, "model"):
                    spec[d] = "model"
                    break
        elif name in ("x_tm", "x_cm"):         # (L, B, D)
            if len(shape) == 3:
                if _div(shape[1], mesh, "data"):
                    spec[1] = "data"
                if _div(shape[2], mesh, "model"):
                    spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
