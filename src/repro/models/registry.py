"""Model registry: arch id -> init / train_loss / prefill / decode_step,
plus ShapeDtypeStruct input specs for every (arch x shape) combination.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeConfig, get_arch
from repro.models import transformer as tfm
from repro.models.layers import COMPUTE_DTYPE


def init_params(key: jax.Array, cfg: ArchConfig):
    return tfm.init_params(key, cfg)


def train_loss_fn(cfg: ArchConfig) -> Callable:
    return functools.partial(tfm.train_loss, cfg)


def prefill_fn(cfg: ArchConfig) -> Callable:
    return functools.partial(tfm.prefill, cfg)


def decode_fn(cfg: ArchConfig, context: int) -> Callable:
    window = 0
    if cfg.sliding_window and context > cfg.sliding_window:
        window = cfg.sliding_window
    return functools.partial(tfm.decode_step, cfg, window=window)


def init_cache(cfg: ArchConfig, batch: int, context: int):
    return tfm.init_cache(cfg, batch, context)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    toks = s
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        toks = s - cfg.num_prefix_tokens
        batch["prefix"] = _sds((b, cfg.num_prefix_tokens, cfg.d_model),
                               COMPUTE_DTYPE)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                               COMPUTE_DTYPE)
    batch["tokens"] = _sds((b, toks), jnp.int32)
    batch["targets"] = _sds((b, toks), jnp.int32)
    batch["mask"] = _sds((b, toks), jnp.float32)
    return batch


def prefill_batch_spec(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    spec = train_batch_spec(cfg, shape)
    spec.pop("targets")
    spec.pop("mask")
    return spec


def decode_inputs_spec(cfg: ArchConfig, shape: ShapeConfig
                       ) -> Tuple[Dict[str, Any], Any]:
    """(tokens spec, cache spec) for a serve_step lowering."""
    b = shape.global_batch
    tokens = _sds((b, 1), jnp.int32)
    cache = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    return {"tokens": tokens}, cache


def make_concrete_batch(cfg: ArchConfig, shape: ShapeConfig,
                        key: jax.Array, kind: str) -> Dict[str, Any]:
    """Small concrete batch (for smoke tests with reduced configs)."""
    spec = (train_batch_spec if kind == "train"
            else prefill_batch_spec)(cfg, shape)
    out = {}
    for k, v in spec.items():
        key, sub = jax.random.split(key)
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, v.shape, 0, cfg.vocab_size)
        elif k == "mask":
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(v.dtype)
    return out
