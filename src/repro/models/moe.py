"""Mixture-of-Experts layer: top-k routing, capacity dispatch, aux losses.

Dispatch uses an argsort-based position-in-expert computation (O(T·k)
memory — no (T, E, C) one-hot tensor) followed by scatter into a per-expert
(E, C, D) buffer.  Under expert-parallel sharding (experts over the
``model`` mesh axis) the scatter/gather lower to all-to-all collectives,
which is exactly what the roofline's collective term should see.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models.layers import Params, dense_init, PARAM_DTYPE
from repro.sharding.api import constrain


def init_moe(key: jax.Array, cfg, d: int) -> Params:
    e = cfg.num_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "wi": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[1], e)),
        "wg": jax.vmap(lambda k: dense_init(k, d, ff))(
            jax.random.split(ks[2], e)),
        "wo": jax.vmap(lambda k: dense_init(k, ff, d))(
            jax.random.split(ks[3], e)),
    }


def _positions_in_expert(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each assignment within its expert (stable order)."""
    tk = flat_e.shape[0]
    perm = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                  # exclusive cumsum
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[flat_e[perm]]
    return jnp.zeros((tk,), jnp.int32).at[perm].set(pos_sorted)


def moe_capacity(cfg, tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.experts_per_token * tokens
              / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)                       # round up to 8


def apply_moe(cfg, p: Params, x: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """x: (B, S, D) -> (B, S, D), aux {lb_loss, z_loss, expert_load}.

    Dispatches to the shard_map expert-parallel path when a production
    mesh is active (see ``_apply_moe_ep``); falls back to the dense
    jit-level dispatch otherwise (CPU tests, debug meshes).
    """
    from repro.sharding.api import current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.shape:
        msz = mesh.shape["model"]
        bsz = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                bsz *= mesh.shape[a]
        # EP pays a per-layer psum + weight gather: only worth it when the
        # token volume dwarfs the expert count (train/prefill, not decode)
        tokens = x.shape[0] * x.shape[1]
        if (cfg.num_experts % msz == 0 and x.shape[0] % bsz == 0
                and msz > 1 and tokens > 8 * cfg.num_experts):
            return _apply_moe_ep(cfg, p, x, mesh)
    return _apply_moe_dense(cfg, p, x)


def _apply_moe_dense(cfg, p: Params, x: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = moe_capacity(cfg, t)
    xt = x.reshape(t, d)
    dt = x.dtype

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, sel = jax.lax.top_k(probs, k)                             # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    flat_e = sel.reshape(-1)                                     # (T*k,)
    pos = _positions_in_expert(flat_e, e)
    keep = (pos < cap).astype(dt)
    pos_c = jnp.minimum(pos, cap - 1)
    tok = jnp.arange(t * k, dtype=jnp.int32) // k

    # dispatch: (E, C, D)
    buf = jnp.zeros((e, cap, d), dt).at[flat_e, pos_c].add(
        xt[tok] * keep[:, None])

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(dt))
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dt))
    y_e = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))

    # combine
    gathered = y_e[flat_e, pos_c] * keep[:, None] * w.reshape(-1)[:, None].astype(dt)
    y = jnp.zeros((t, d), dt).at[tok].add(gathered)

    # aux losses (Switch-style load balance + router z-loss)
    me = probs.mean(0)                                           # (E,)
    assign = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    lb = e * jnp.sum(me * assign)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"lb_loss": lb, "z_loss": z, "expert_load": assign}
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------------
# shard_map expert parallelism
# --------------------------------------------------------------------------
#
# Tokens are sharded over ('pod','data') and *replicated over 'model'*
# (the residual stream is model-replicated), so every model shard can
# route the full local token block and process only its own E/m experts:
# no all-to-all is needed for dispatch, and the combine is one psum over
# 'model' of the (T_local, D) partial outputs.  Expert weights are stored
# ZeRO-style as (E->'model', dim1->'data') and all-gathered over 'data'
# at use (in bf16).  Capacity is computed from *local* tokens, which keeps
# the dispatch buffer device-sized — the flaw of the jit-level dense path
# at production scale (a global-capacity (E, C, D) buffer that GSPMD
# cannot shard through the scatter).

def _apply_moe_ep(cfg, p: Params, x: jax.Array, mesh
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    from jax.sharding import PartitionSpec as P

    e, k = cfg.num_experts, cfg.experts_per_token
    msz = mesh.shape["model"]
    e_loc = e // msz
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    data_ax = "data" if "data" in mesh.shape else None
    dt = x.dtype

    def body(x_blk, router, wi, wg, wo):
        bl, s, d = x_blk.shape
        xt = x_blk.reshape(-1, d)
        tl = xt.shape[0]
        logits = (xt @ router.astype(dt)).astype(jnp.float32)   # (Tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        w, sel = jax.lax.top_k(probs, k)
        w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(dt)

        flat_e = sel.reshape(-1)
        pos = _positions_in_expert(flat_e, e).reshape(tl, k)
        cap = moe_capacity(cfg, tl)

        m_idx = jax.lax.axis_index("model")
        # per-routing-slot scatters: transients stay (T_local, D), not
        # (T_local*k, D)
        buf = jnp.zeros((e_loc, cap, d), dt)
        slot = []
        for j in range(k):
            ej, pj = sel[:, j], pos[:, j]
            mine = (pj < cap) & (ej >= m_idx * e_loc) \
                & (ej < (m_idx + 1) * e_loc)
            le = jnp.clip(ej - m_idx * e_loc, 0, e_loc - 1)
            pc = jnp.minimum(pj, cap - 1)
            buf = buf.at[le, pc].add(xt * mine.astype(dt)[:, None])
            slot.append((le, pc, mine))

        def full(wt):
            if data_ax is None:
                return wt.astype(dt)
            return jax.lax.all_gather(wt.astype(dt), data_ax, axis=1,
                                      tiled=True)

        h = jnp.einsum("ecd,edf->ecf", buf, full(wi))
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, full(wg))
        y_e = jnp.einsum("ecf,efd->ecd", h, full(wo))

        y = jnp.zeros((tl, d), dt)
        for j, (le, pc, mine) in enumerate(slot):
            y = y + y_e[le, pc] * mine.astype(dt)[:, None] * w[:, j, None]
        y = jax.lax.psum(y, "model")

        me = probs.mean(0)
        assign = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (tl * k)
        lb = e * jnp.sum(me * assign)
        z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        if batch_axes:
            lb = jax.lax.pmean(lb, batch_axes)
            z = jax.lax.pmean(z, batch_axes)
            assign = jax.lax.pmean(assign, batch_axes)
        return y.reshape(bl, s, d), lb, z, assign

    xspec = P(batch_axes if batch_axes else None, None, None)
    wspec = P("model", "data" if data_ax else None, None)
    y, lb, z, assign = shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None), wspec, wspec, wspec),
        out_specs=(xspec, P(), P(), P()),
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return y, {"lb_loss": lb, "z_loss": z, "expert_load": assign}
