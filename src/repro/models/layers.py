"""Shared neural-net primitives (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays.  All ``init_*`` functions
return fp32 params; ``apply`` paths cast to the compute dtype (bf16 by
default) and keep normalization / softmax accumulation in fp32.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int,
               scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init (what llama-family models use)."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out),
                                        PARAM_DTYPE) * scale)


def embed_init(key: jax.Array, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d), PARAM_DTYPE) * 0.02


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def init_norm(cfg, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), PARAM_DTYPE),
                "b": jnp.zeros((d,), PARAM_DTYPE)}
    return {"w": jnp.zeros((d,), PARAM_DTYPE)}   # rmsnorm stores (weight-1)


def apply_norm(cfg, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings.  x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]                        # (...,S,1,half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rot.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (gated and plain)
# --------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg, d: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    if cfg.hidden_act in ("silu", "geglu"):
        return {"wi": dense_init(ks[0], d, d_ff),
                "wg": dense_init(ks[1], d, d_ff),
                "wo": dense_init(ks[2], d_ff, d)}
    return {"wi": dense_init(ks[0], d, d_ff),
            "wo": dense_init(ks[2], d_ff, d)}


def apply_mlp(cfg, p: Params, x: jax.Array) -> jax.Array:
    from repro.sharding.api import constrain
    dt = x.dtype
    ff_spec = ("batch",) + (None,) * (x.ndim - 2) + ("ff",)
    h = constrain(x @ p["wi"].astype(dt), ff_spec)
    if cfg.hidden_act == "silu":
        h = jax.nn.silu(h) * constrain(x @ p["wg"].astype(dt), ff_spec)
    elif cfg.hidden_act == "geglu":
        h = jax.nn.gelu(h, approximate=True) * constrain(
            x @ p["wg"].astype(dt), ff_spec)
    elif cfg.hidden_act == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif cfg.hidden_act == "relu_sq":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(cfg.hidden_act)
    return h @ p["wo"].astype(dt)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def chunked_cross_entropy(x: jax.Array, embed: jax.Array,
                          labels: jax.Array, mask: jax.Array,
                          head: Optional[jax.Array] = None,
                          softcap: float = 0.0,
                          chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materialising (B,S,V) logits.

    x: (B,S,D) final hidden states; embed: (V,D) used transposed (or
    ``head`` (D,V) if untied).  Scans over sequence chunks; logits exist
    only per-chunk.  Returns (sum_loss, sum_mask).
    """
    b, s, d = x.shape
    w = head if head is not None else embed.T            # (D, V)
    n_chunks = max(1, s // chunk)
    while s % n_chunks:                                   # largest divisor
        n_chunks -= 1
    chunk = s // n_chunks
    xs = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = (xc @ w.astype(xc.dtype)).astype(jnp.float32)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (carry[0] + nll.sum(), carry[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xs, ls, ms))
    return tot, cnt
