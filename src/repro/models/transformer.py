"""Unified model assembly for all assigned architectures.

One parameter/apply scheme covers the six families:

- dense / moe:     L identical decoder layers  -> single lax.scan
- ssm (rwkv6):     L identical rwkv blocks     -> single lax.scan
- hybrid (jamba):  4 identical *groups* of 8 heterogeneous layers
                   -> lax.scan over groups, unrolled inside
- audio (whisper): encoder stack (scan) + decoder stack with cross-attn
- vlm (paligemma): dense decoder consuming prefix patch embeddings with
                   prefix-LM masking

Three entry points per model (see ``registry.py``): ``train_loss``,
``prefill`` and ``decode_step``.  Caches are slot-indexed pytrees whose
leading axis matches the scan axis, so decode scans carry them as xs/ys.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models.layers import (COMPUTE_DTYPE, PARAM_DTYPE, Params,
                                 apply_mlp, apply_norm, chunked_cross_entropy,
                                 dense_init, embed_init, init_mlp, init_norm)
from repro.sharding.api import constrain

ZERO_AUX = lambda: {"lb_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}


# ==========================================================================
# init
# ==========================================================================

def _init_dense_layer(key: jax.Array, cfg: ArchConfig, is_moe: bool) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"n1": init_norm(cfg, cfg.d_model),
         "n2": init_norm(cfg, cfg.d_model),
         "attn": attn.init_attention(k1, cfg, cfg.d_model)}
    if is_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg, cfg.d_model)
    else:
        p["mlp"] = init_mlp(k3, cfg, cfg.d_model, cfg.d_ff)
    return p


def _init_mamba_layer(key: jax.Array, cfg: ArchConfig, is_moe: bool) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"n1": init_norm(cfg, cfg.d_model),
         "n2": init_norm(cfg, cfg.d_model),
         "mamba": mam.init_mamba_layer(k1, cfg)}
    if is_moe:
        p["moe"] = moe_mod.init_moe(k2, cfg, cfg.d_model)
    else:
        p["mlp"] = init_mlp(k3, cfg, cfg.d_model, cfg.d_ff)
    return p


def _init_rwkv_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    return {"n1": init_norm(cfg, cfg.d_model),
            "n2": init_norm(cfg, cfg.d_model),
            "rwkv": rwkv.init_rwkv_layer(key, cfg)}


def _init_whisper_enc_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"n1": init_norm(cfg, cfg.d_model),
            "n2": init_norm(cfg, cfg.d_model),
            "attn": attn.init_attention(k1, cfg, cfg.d_model),
            "mlp": init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)}


def _init_whisper_dec_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"n1": init_norm(cfg, cfg.d_model),
            "nc": init_norm(cfg, cfg.d_model),
            "n2": init_norm(cfg, cfg.d_model),
            "attn": attn.init_attention(k1, cfg, cfg.d_model),
            "xattn": attn.init_cross_attention(k2, cfg, cfg.d_model),
            "mlp": init_mlp(k3, cfg, cfg.d_model, cfg.d_ff)}


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    """Build the full parameter pytree for any assigned architecture."""
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size)

    if cfg.family == "ssm":                                   # rwkv6
        lk = jax.random.split(keys[2], cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_rwkv_layer(k, cfg))(lk)
    elif cfg.family == "hybrid":                              # jamba
        period = cfg.attn_layer_period
        n_groups = cfg.num_layers // period
        def one_group(k):
            ks = jax.random.split(k, period)
            return tuple(
                (_init_dense_layer(ks[i], cfg, cfg.layer_is_moe(i))
                 if cfg.layer_kind(i) == "attn"
                 else _init_mamba_layer(ks[i], cfg, cfg.layer_is_moe(i)))
                for i in range(period))
        gk = jax.random.split(keys[2], n_groups)
        params["blocks"] = jax.vmap(one_group)(gk)
    elif cfg.family == "audio":                               # whisper
        ek = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_whisper_enc_layer(k, cfg))(ek),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
        dk = jax.random.split(keys[2], cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_whisper_dec_layer(k, cfg))(dk)
    else:                                                     # dense/moe/vlm
        lk = jax.random.split(keys[2], cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: _init_dense_layer(k, cfg, cfg.layer_is_moe(0)))(lk)
    return params


# ==========================================================================
# caches
# ==========================================================================

def init_cache(cfg: ArchConfig, batch: int, context: int) -> Params:
    """Decode cache pytree.  ``context`` = total positions the serve step
    must be able to attend over; sliding-window archs allocate only the
    window (ring buffer)."""
    hkv, dh = cfg.num_kv_heads, cfg.head_dim

    def kv_slots() -> int:
        if cfg.sliding_window and context > cfg.sliding_window:
            return cfg.sliding_window
        return context

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    if cfg.family == "ssm":
        st = rwkv.init_rwkv_state(cfg, batch)
        return {"layers": stack(st, cfg.num_layers)}
    if cfg.family == "hybrid":
        period = cfg.attn_layer_period
        n_groups = cfg.num_layers // period
        group = tuple(
            (attn.make_kv_cache(batch, kv_slots(), hkv, dh)
             if cfg.layer_kind(i) == "attn"
             else mam.init_mamba_state(cfg, batch))
            for i in range(period))
        return {"layers": stack(group, n_groups)}
    if cfg.family == "audio":
        kv = attn.make_kv_cache(batch, kv_slots(), hkv, dh)
        xk = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, hkv, dh),
                       COMPUTE_DTYPE)
        return {"layers": stack(kv, cfg.num_layers),
                "cross_k": xk, "cross_v": xk}
    kv = attn.make_kv_cache(batch, kv_slots(), hkv, dh)
    return {"layers": stack(kv, cfg.num_layers)}


# ==========================================================================
# layer bodies
# ==========================================================================

def _ffn(cfg: ArchConfig, lp: Params, x: jax.Array, is_moe: bool):
    h = apply_norm(cfg, lp["n2"], x)
    if is_moe:
        y, aux = moe_mod.apply_moe(cfg, lp["moe"], h)
        return y, {"lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
    return apply_mlp(cfg, lp["mlp"], h), ZERO_AUX()


def _dense_layer_full(cfg, lp, x, positions, *, is_moe, window=0,
                      prefix_len=0, return_kv=False):
    h = apply_norm(cfg, lp["n1"], x)
    out = attn.attn_apply_full(cfg, lp["attn"], h, positions, window=window,
                               prefix_len=prefix_len, return_kv=return_kv)
    y, kv = out if return_kv else (out, None)
    x = x + y * cfg.residual_scale
    x = constrain(x, ("batch", None, "embed"))
    y, aux = _ffn(cfg, lp, x, is_moe)
    x = x + y * cfg.residual_scale
    x = constrain(x, ("batch", None, "embed"))
    return x, kv, aux


def _dense_layer_decode(cfg, lp, x, cache, *, is_moe, window=0, prefix_len=0):
    h = apply_norm(cfg, lp["n1"], x)
    y, cache = attn.attn_apply_decode(cfg, lp["attn"], h, cache,
                                      window=window, prefix_len=prefix_len)
    x = x + y * cfg.residual_scale
    y, _ = _ffn(cfg, lp, x, is_moe)
    x = x + y * cfg.residual_scale
    return x, cache


def _mamba_layer(cfg, lp, x, state, *, is_moe):
    h = apply_norm(cfg, lp["n1"], x)
    y, new_state = mam.mamba_apply(cfg, lp["mamba"], h, state)
    x = x + y * cfg.residual_scale
    x = constrain(x, ("batch", None, "embed"))
    y, aux = _ffn(cfg, lp, x, is_moe)
    x = x + y * cfg.residual_scale
    x = constrain(x, ("batch", None, "embed"))
    return x, new_state, aux


# ==========================================================================
# stacks (scan over layers)
# ==========================================================================

def _sum_aux(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def _run_dense_stack(cfg, params, x, positions, *, mode, cache=None,
                     window=0, prefix_len=0, remat=False, context=0):
    """mode: 'train' | 'prefill' | 'decode'.  Returns (x, new_cache, aux)."""
    is_moe = cfg.layer_is_moe(0) if cfg.is_moe else False

    if mode == "decode":
        def body(h, xs):
            lp, c = xs
            h, c = _dense_layer_decode(cfg, lp, h, c, is_moe=is_moe,
                                       window=window, prefix_len=prefix_len)
            return h, c
        x, new_layers = jax.lax.scan(body, x, (params["blocks"],
                                               cache["layers"]))
        return x, {"layers": new_layers}, ZERO_AUX()

    build_cache = mode == "prefill"

    def body(h, lp):
        h, kv, aux = _dense_layer_full(cfg, lp, h, positions, is_moe=is_moe,
                                       window=window, prefix_len=prefix_len,
                                       return_kv=build_cache)
        return h, (kv, aux)
    if remat:
        body = jax.checkpoint(body)
    x, (kvs, auxs) = jax.lax.scan(body, x, params["blocks"])
    aux = jax.tree.map(lambda a: a.sum(0), auxs)
    new_cache = None
    if build_cache:
        new_cache = _kvs_to_cache(cfg, kvs, positions, context)
    return x, new_cache, aux


def _kvs_to_cache(cfg, kvs, positions, context: int = 0):
    """Turn prefill (L,B,S,Hkv,Dh) K/V stacks into a slot cache pytree.

    ``context`` is the total number of positions the cache must serve
    (prompt + decode headroom); without it, the first decode step would
    ring-wrap onto slot 0 and silently drop the first prompt token."""
    k, v = kvs
    l, b, s, hkv, dh = k.shape
    slots = max(s, context)
    if cfg.sliding_window and slots > cfg.sliding_window:
        w = cfg.sliding_window
        # keep the last `w` positions; their ring slots are a pure
        # rotation (slot = pos % w and the tail is contiguous), so a
        # static roll places them — no gather/scatter in the graph.
        keep = min(w, s)
        shift = int(s % w)
        if keep < w:                      # short prompt: pad then roll
            padk = jnp.zeros((l, b, w - keep, hkv, dh), COMPUTE_DTYPE)
            k_tail = jnp.concatenate(
                [k[:, :, -keep:].astype(COMPUTE_DTYPE), padk], axis=2)
            v_tail = jnp.concatenate(
                [v[:, :, -keep:].astype(COMPUTE_DTYPE), padk], axis=2)
            tail_pos = jnp.concatenate(
                [positions[-keep:].astype(jnp.int32),
                 jnp.full((w - keep,), -1, jnp.int32)])
            shift = int((s - keep) % w)
        else:
            k_tail = k[:, :, -w:].astype(COMPUTE_DTYPE)
            v_tail = v[:, :, -w:].astype(COMPUTE_DTYPE)
            tail_pos = positions[-w:].astype(jnp.int32)
        k = jnp.roll(k_tail, shift, axis=2)
        v = jnp.roll(v_tail, shift, axis=2)
        pos = jnp.roll(tail_pos, shift)
        slots = w
    else:
        pad = slots - s
        if pad:
            zk = jnp.zeros((l, b, pad, hkv, dh), COMPUTE_DTYPE)
            k = jnp.concatenate([k.astype(COMPUTE_DTYPE), zk], axis=2)
            v = jnp.concatenate([v.astype(COMPUTE_DTYPE), zk], axis=2)
            pos = jnp.concatenate([positions.astype(jnp.int32),
                                   jnp.full((pad,), -1, jnp.int32)])
        else:
            pos = positions.astype(jnp.int32)
    cache = {
        "k": k.astype(COMPUTE_DTYPE), "v": v.astype(COMPUTE_DTYPE),
        "pos": jnp.broadcast_to(pos, (l, slots)),
        "idx": jnp.full((l,), positions.shape[0], jnp.int32),
    }
    return {"layers": cache}


def _run_rwkv_stack(cfg, params, x, *, mode, cache=None, remat=False):
    if mode == "decode":
        def body(h, xs):
            lp, st = xs
            h, st = rwkv.rwkv_layer_apply(cfg, lp["rwkv"],
                                          {"n1": lp["n1"]["w"],
                                           "n2": lp["n2"]["w"]}, h, st)
            return h, st
        x, new_states = jax.lax.scan(body, x, (params["blocks"],
                                               cache["layers"]))
        return x, {"layers": new_states}, ZERO_AUX()

    def body(h, lp):
        h, st = rwkv.rwkv_layer_apply(cfg, lp["rwkv"],
                                      {"n1": lp["n1"]["w"],
                                       "n2": lp["n2"]["w"]}, h, None)
        return h, st
    if remat:
        body = jax.checkpoint(body)
    x, states = jax.lax.scan(body, x, params["blocks"])
    new_cache = {"layers": states} if mode == "prefill" else None
    return x, new_cache, ZERO_AUX()


def _run_hybrid_stack(cfg, params, x, positions, *, mode, cache=None,
                      window=0, remat=False, context=0):
    period = cfg.attn_layer_period

    def group_body(h, xs):
        if mode == "decode":
            gp, gc = xs
        else:
            gp, gc = xs, tuple(None for _ in range(period))
        new_caches = []
        aux = ZERO_AUX()
        for i in range(period):
            lp = gp[i]
            is_moe = cfg.layer_is_moe(i)
            if cfg.layer_kind(i) == "attn":
                if mode == "decode":
                    h, c = _dense_layer_decode(cfg, lp, h, gc[i],
                                               is_moe=is_moe, window=window)
                    new_caches.append(c)
                else:
                    h, kv, a = _dense_layer_full(cfg, lp, h, positions,
                                                 is_moe=is_moe, window=window,
                                                 return_kv=(mode == "prefill"))
                    aux = _sum_aux(aux, a)
                    new_caches.append(kv)
            else:
                st = gc[i] if mode == "decode" else None
                h, st, a = _mamba_layer(cfg, lp, h, st, is_moe=is_moe)
                aux = _sum_aux(aux, a)
                new_caches.append(st)
        return h, (tuple(new_caches), aux)

    body = group_body
    if remat and mode == "train":
        body = jax.checkpoint(group_body)

    if mode == "decode":
        x, (new_layers, _) = jax.lax.scan(body, x, (params["blocks"],
                                                    cache["layers"]))
        return x, {"layers": new_layers}, ZERO_AUX()

    x, (outs, auxs) = jax.lax.scan(body, x, params["blocks"])
    aux = jax.tree.map(lambda a: a.sum(0), auxs)
    new_cache = None
    if mode == "prefill":
        # attn slots carry (k, v); mamba slots carry state dicts
        layers = []
        s = positions.shape[0]
        for i in range(period):
            if cfg.layer_kind(i) == "attn":
                kv_cache = _kvs_to_cache(cfg, outs[i], positions,
                                         context)["layers"]
                layers.append(kv_cache)
            else:
                layers.append(outs[i])
        new_cache = {"layers": tuple(layers)}
    return x, new_cache, aux


def _sinusoidal(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def run_encoder(cfg, params, frames: jax.Array, *, remat=False) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (B, S_enc, D)."""
    x = frames.astype(COMPUTE_DTYPE)
    x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)
    pos = jnp.arange(x.shape[1])

    def body(h, lp):
        a = attn.attn_apply_full(cfg, lp["attn"],
                                 apply_norm(cfg, lp["n1"], h), pos,
                                 causal=False, use_rope=False)
        h = h + a
        h = h + apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["n2"], h))
        h = constrain(h, ("batch", None, "embed"))
        return h, None
    if remat:
        body = jax.checkpoint(body)
    enc = params["encoder"]
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(cfg, enc["final_norm"], x)


def _run_whisper_decoder(cfg, params, x, positions, *, mode, enc=None,
                         cache=None, window=0, remat=False, context=0):
    if mode == "decode":
        def body(h, xs):
            lp, c, xk, xv = xs
            a, c = attn.attn_apply_decode(cfg, lp["attn"],
                                          apply_norm(cfg, lp["n1"], h), c,
                                          window=window)
            h = h + a
            h = h + attn.cross_attn_apply(cfg, lp["xattn"],
                                          apply_norm(cfg, lp["nc"], h), xk, xv)
            h = h + apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["n2"], h))
            return h, c
        x, new_layers = jax.lax.scan(
            body, x, (params["blocks"], cache["layers"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, layers=new_layers)
        return x, new_cache, ZERO_AUX()

    build_cache = mode == "prefill"

    def body(h, lp):
        a = attn.attn_apply_full(cfg, lp["attn"],
                                 apply_norm(cfg, lp["n1"], h), positions,
                                 window=window, return_kv=build_cache)
        a, kv = a if build_cache else (a, None)
        h = h + a
        xk, xv = attn.encoder_kv(cfg, lp["xattn"], enc)
        h = h + attn.cross_attn_apply(cfg, lp["xattn"],
                                      apply_norm(cfg, lp["nc"], h), xk, xv)
        h = h + apply_mlp(cfg, lp["mlp"], apply_norm(cfg, lp["n2"], h))
        h = constrain(h, ("batch", None, "embed"))
        return h, (kv, (xk, xv))
    if remat:
        body = jax.checkpoint(body)
    x, (kvs, xkvs) = jax.lax.scan(body, x, params["blocks"])
    new_cache = None
    if build_cache:
        new_cache = _kvs_to_cache(cfg, kvs, positions, context)
        new_cache["cross_k"] = xkvs[0].astype(COMPUTE_DTYPE)
        new_cache["cross_v"] = xkvs[1].astype(COMPUTE_DTYPE)
    return x, new_cache, ZERO_AUX()


# ==========================================================================
# forward passes
# ==========================================================================

def _embed(cfg, params, tokens):
    x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    return x


def _head_weights(cfg, params):
    head = params.get("lm_head")
    return params["embed"], head


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            mode: str, cache: Optional[Params] = None, window: int = 0,
            remat: bool = False, context: int = 0):
    """Shared forward.  Returns (hidden (B,S,D), new_cache, aux, prefix_len)."""
    prefix_len = 0
    if cfg.family == "vlm" and mode != "decode":
        prefix = batch["prefix"].astype(COMPUTE_DTYPE)       # (B,P,D)
        tok_x = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([prefix, tok_x], axis=1)
        prefix_len = cfg.num_prefix_tokens
    elif mode == "decode":
        x = _embed(cfg, params, batch["tokens"])             # (B,1,D)
    else:
        x = _embed(cfg, params, batch["tokens"])
    x = constrain(x, ("batch", None, "embed"))
    s = x.shape[1]
    positions = jnp.arange(s)

    if cfg.family == "ssm":
        x, new_cache, aux = _run_rwkv_stack(cfg, params, x, mode=mode,
                                            cache=cache, remat=remat)
    elif cfg.family == "hybrid":
        x, new_cache, aux = _run_hybrid_stack(cfg, params, x, positions,
                                              mode=mode, cache=cache,
                                              window=window, remat=remat,
                                              context=context)
    elif cfg.family == "audio":
        enc = None
        if mode != "decode":
            enc = run_encoder(cfg, params, batch["frames"], remat=remat)
        x, new_cache, aux = _run_whisper_decoder(cfg, params, x, positions,
                                                 mode=mode, enc=enc,
                                                 cache=cache, window=window,
                                                 remat=remat, context=context)
    else:
        x, new_cache, aux = _run_dense_stack(cfg, params, x, positions,
                                             mode=mode, cache=cache,
                                             window=window,
                                             prefix_len=prefix_len,
                                             remat=remat, context=context)
    x = apply_norm(cfg, params["final_norm"], x)
    return x, new_cache, aux, prefix_len


# --------------------------------------------------------------------------

MOE_LB_COEF = 0.01
MOE_Z_COEF = 0.001


def train_loss(cfg: ArchConfig, params: Params,
               batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    """Next-token CE over the batch.  batch keys: tokens, targets, mask
    (+ frames for audio, prefix for vlm)."""
    x, _, aux, prefix_len = forward(cfg, params, batch, mode="train",
                                    remat=True)
    if prefix_len:
        x = x[:, prefix_len:]
    embed, head = _head_weights(cfg, params)
    tot, cnt = chunked_cross_entropy(x, embed, batch["targets"],
                                     batch["mask"].astype(jnp.float32),
                                     head=head, softcap=cfg.logit_softcap)
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce
    if cfg.is_moe:
        loss = loss + MOE_LB_COEF * aux["lb_loss"] + MOE_Z_COEF * aux["z_loss"]
    metrics = {"ce": ce, "loss": loss, "tokens": cnt,
               "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
    return loss, metrics


def prefill(cfg: ArchConfig, params: Params,
            batch: Dict[str, jax.Array],
            context: int = 0, window: int = 0) -> Tuple[jax.Array, Params]:
    """Run the full prompt; return last-position logits + decode cache.
    ``context`` sizes the cache for prompt + decode headroom; ``window``
    applies sliding-window masking during the prompt pass (matching a
    windowed decode)."""
    x, cache, _, _ = forward(cfg, params, batch, mode="prefill",
                             context=context, window=window)
    embed, head = _head_weights(cfg, params)
    w = head if head is not None else embed.T
    logits = (x[:, -1:] @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jax.Array,
                window: int = 0) -> Tuple[jax.Array, Params]:
    """One decode step: tokens (B,1) -> logits (B,1,V), updated cache."""
    x, cache, _, _ = forward(cfg, params, {"tokens": tokens}, mode="decode",
                             cache=cache, window=window)
    embed, head = _head_weights(cfg, params)
    w = head if head is not None else embed.T
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return logits, cache
