"""RWKV-6 (Finch) block: data-dependent decay WKV recurrence + channel mix.

The WKV6 recurrence is the compute hot-spot: ``kernels/wkv6.py`` holds the
Pallas TPU kernel; this module calls ``kernels.ops.wkv6`` which dispatches
to the pure-jnp chunked scan below (the oracle) unless the Pallas path is
requested.  Training memory: the time scan is chunked (outer scan over
chunks with ``jax.checkpoint``) so backprop stores only per-chunk states.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, rms_norm, PARAM_DTYPE

SCAN_CHUNK = 256


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_rwkv_layer(key: jax.Array, cfg) -> Params:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    r = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": jax.random.uniform(ks[0], (5, d), PARAM_DTYPE),   # lerp r,k,v,g,w
        "wr": dense_init(ks[1], d, d),
        "wk": dense_init(ks[2], d, d),
        "wv": dense_init(ks[3], d, d),
        "wg": dense_init(ks[4], d, d),
        "wo": dense_init(ks[5], d, d),
        "w0": jnp.full((d,), -6.0, PARAM_DTYPE),                # decay bias
        "wA": dense_init(ks[6], d, r, scale=0.01),
        "wB": dense_init(ks[7], r, d, scale=0.01),
        "u": jax.random.normal(ks[8], (d,), PARAM_DTYPE) * 0.1,  # bonus
        "ln_x": jnp.zeros((d,), PARAM_DTYPE),                   # per-head norm
        # channel-mix
        "mu_c": jax.random.uniform(ks[9], (2, d), PARAM_DTYPE),
        "ck": dense_init(ks[10], d, cfg.d_ff),
        "cv": dense_init(ks[11], cfg.d_ff, d),
    }


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    n = cfg.rwkv_head_size
    h = d // n
    return {
        "x_tm": jnp.zeros((batch, d), dtype),       # last input (time mix)
        "x_cm": jnp.zeros((batch, d), dtype),       # last input (channel mix)
        "S": jnp.zeros((batch, h, n, n), jnp.float32),
    }


# --------------------------------------------------------------------------
# WKV6 recurrence — pure-jnp oracle (chunked scan)
# --------------------------------------------------------------------------

def wkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, s0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N) -> y (B,T,H,N), sT.

    y_t = r_t · (S + u⊙k_t ⊗ v_t);  S ← diag(w_t)·S + k_t ⊗ v_t.
    fp32 state; chunked with checkpoint for O(T/C) saved states.
    """
    b, t, h, n = r.shape
    c = SCAN_CHUNK if t % SCAN_CHUNK == 0 else t
    nc = t // c

    def step(s, inp):
        rt, kt, vt, wt = inp                         # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,N,N)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    @jax.checkpoint
    def chunk(s, inp):
        rs, ks_, vs, ws = inp                        # (C,B,H,N)
        return jax.lax.scan(step, s, (rs, ks_, vs, ws))

    def outer(s, inp):
        return chunk(s, inp)

    rs = r.astype(jnp.float32).reshape(b, nc, c, h, n).transpose(1, 2, 0, 3, 4)
    ks_ = k.astype(jnp.float32).reshape(b, nc, c, h, n).transpose(1, 2, 0, 3, 4)
    vs = v.astype(jnp.float32).reshape(b, nc, c, h, n).transpose(1, 2, 0, 3, 4)
    ws = w.astype(jnp.float32).reshape(b, nc, c, h, n).transpose(1, 2, 0, 3, 4)
    sT, ys = jax.lax.scan(outer, s0, (rs, ks_, vs, ws))
    y = ys.transpose(2, 0, 1, 3, 4).reshape(b, t, h, n)
    return y.astype(r.dtype), sT


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                 u: jax.Array, s0: jax.Array,
                 chunk: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Chunked matmul formulation of the WKV6 recurrence (TPU-native).

    Within a chunk of C steps with log-decays L_t = sum_{u<=t} log w_u:
      y_t = (r_t ⊙ e^{L_{t-1}}) · S_0
            + Σ_{s<t} [Σ_n r_t[n] k_s[n] e^{L_{t-1}[n]-L_s[n]}] v_s
            + (r_t · (u ⊙ k_t)) v_t
      S_C = diag(e^{L_C}) S_0 + Σ_s (k_s ⊙ e^{L_C - L_s}) v_s^T
    Every exponent is <= 0 (L is non-increasing), so the chunk math is
    numerically safe in fp32.  Converts T per-step state updates into
    T/C MXU matmuls — the jnp shadow of the Pallas kernel's VMEM-resident
    state (kernels/wkv6.py), and the structure a TPU actually wants.
    """
    b, t, h, n = r.shape
    if chunk == 0:
        # dry-run-swept optimum: larger chunks amortize per-chunk state
        # traffic; the (C,C,N) score tensor grows with C — crossover ~8k
        chunk = 128 if t <= 8192 else 256
    c = chunk if t % chunk == 0 else t
    nc = t // c
    f32 = jnp.float32
    rr, kk, vv, ww = (z.astype(f32) for z in (r, k, v, w))
    uu = u.astype(f32)

    # (nc, B, H, C, N) chunk-major
    cm = lambda z: z.reshape(b, nc, c, h, n).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = cm(rr), cm(kk), cm(vv), cm(ww)

    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)          # s < t

    @jax.checkpoint
    def chunk_fn(s, inp):
        rch, kch, vch, wch = inp                          # (B,H,C,N)
        lw = jnp.log(jnp.maximum(wch, 1e-30))   # > FLT_MIN: no FTZ to -inf
        lcum = jnp.cumsum(lw, axis=2)                     # L_t
        lprev = lcum - lw                                 # L_{t-1}
        r_hat = rch * jnp.exp(lprev)                      # decayed queries
        # pairwise decay-weighted scores (exponents <= 0)
        expdiff = jnp.exp(jnp.where(
            tri[None, None, :, :, None],
            lprev[:, :, :, None, :] - lcum[:, :, None, :, :], -1e30))
        scores = jnp.einsum("bhtn,bhsn,bhtsn->bhts", rch, kch, expdiff)
        bonus = jnp.einsum("bhtn,bhtn->bht", rch, uu[None, :, None, :] * kch)
        y = (jnp.einsum("bhtn,bhnm->bhtm", r_hat, s)
             + jnp.einsum("bhts,bhsm->bhtm", scores, vch)
             + bonus[..., None] * vch)
        # state to end of chunk
        lC = lcum[:, :, -1:, :]                           # (B,H,1,N)
        k_hat = kch * jnp.exp(lC - lcum)
        s = (jnp.exp(lC[:, :, 0, :, None]) * s
             + jnp.einsum("bhsn,bhsm->bhnm", k_hat, vch))
        return s, y

    sT, ys = jax.lax.scan(chunk_fn, s0.astype(f32), (rc, kc, vc, wc))
    # ys: (nc, B, H, C, N) -> (B, T, H, N)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, t, h, n)
    return y.astype(r.dtype), sT


def wkv6_step(r, k, v, w, u, s):
    """Single decode step.  r..w: (B,H,N); s: (B,H,N,N)."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r, s + u[..., :, None] * kv)
    s = w[..., :, None] * s + kv
    return y, s


# --------------------------------------------------------------------------
# block apply
# --------------------------------------------------------------------------

def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """Data-dependent decay in (0,1): exp(-exp(w0 + tanh(x A) B))."""
    dt = xw.dtype
    lora = jnp.tanh(xw @ p["wA"].astype(dt)) @ p["wB"].astype(dt)
    return jnp.exp(-jnp.exp(
        (p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))))


def _heads(x: jax.Array, h: int, n: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (h, n))


def time_mix_apply(cfg, p: Params, x: jax.Array,
                   state: Optional[Params]) -> Tuple[jax.Array, Dict]:
    """x: (B,S,D).  state None => train/prefill from zeros."""
    b, s, d = x.shape
    n = cfg.rwkv_head_size
    h = d // n
    dt = x.dtype
    if s == 1 and state is not None:
        x_prev = state["x_tm"][:, None, :].astype(dt)
    else:
        first = (jnp.zeros((b, 1, d), dt) if state is None
                 else state["x_tm"][:, None, :].astype(dt))
        x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)

    mu = p["mu"].astype(dt)
    xr, xk, xv, xg, xw = (x_prev + mu[i] * (x - x_prev) for i in range(5))
    r = _heads(xr @ p["wr"].astype(dt), h, n)
    k = _heads(xk @ p["wk"].astype(dt), h, n)
    v = _heads(xv @ p["wv"].astype(dt), h, n)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    w = _heads(_decay(p, xw), h, n)
    u = _heads(p["u"].astype(jnp.float32), h, n)

    s0 = (jnp.zeros((b, h, n, n), jnp.float32) if state is None
          else state["S"])
    if s == 1:
        y, sT = wkv6_step(r[:, 0].astype(jnp.float32),
                          k[:, 0].astype(jnp.float32),
                          v[:, 0].astype(jnp.float32),
                          w[:, 0], u, s0)
        y = y[:, None].astype(dt)
    else:
        from repro.kernels import ops as kops
        y, sT = kops.wkv6(r, k, v, w.astype(jnp.float32), u, s0)
        y = y.reshape(b, s, h, n)
    y = y.reshape(b, s, d)
    y = rms_norm(y, p["ln_x"])                       # stand-in for groupnorm
    out = (y * g) @ p["wo"].astype(dt)
    new_state = {"x_tm": x[:, -1, :], "S": sT}
    return out, new_state


def channel_mix_apply(cfg, p: Params, x: jax.Array,
                      state: Optional[Params]) -> Tuple[jax.Array, Dict]:
    b, s, d = x.shape
    dt = x.dtype
    if s == 1 and state is not None:
        x_prev = state["x_cm"][:, None, :].astype(dt)
    else:
        first = (jnp.zeros((b, 1, d), dt) if state is None
                 else state["x_cm"][:, None, :].astype(dt))
        x_prev = jnp.concatenate([first, x[:, :-1]], axis=1)
    mu = p["mu_c"].astype(dt)
    xk = x_prev + mu[0] * (x - x_prev)
    xr = x_prev + mu[1] * (x - x_prev)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"].astype(dt)))
    out = jax.nn.sigmoid(xr) * (kk @ p["cv"].astype(dt))
    return out, {"x_cm": x[:, -1, :]}


def rwkv_layer_apply(cfg, p: Params, norms: Params, x: jax.Array,
                     state: Optional[Params]) -> Tuple[jax.Array, Params]:
    """Pre-norm residual block: time-mix then channel-mix."""
    h1, st_tm = time_mix_apply(cfg, p, rms_norm(x, norms["n1"]), state)
    x = x + h1
    h2, st_cm = channel_mix_apply(cfg, p, rms_norm(x, norms["n2"]), state)
    x = x + h2
    new_state = {**st_tm, **st_cm}
    return x, new_state
