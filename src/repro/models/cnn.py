"""The paper's 7-layer MNIST CNN (conv,pool,conv,pool,flatten,fc,fc).

This is the *local model* every FL participant trains (paper §6.1,
~1.66M trainable variables).  Pure JAX; NHWC layout.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import CNNConfig
from repro.models.layers import Params


def init_cnn(key: jax.Array, cfg: CNNConfig) -> Params:
    k = cfg.kernel_size
    c1, c2 = cfg.conv_channels
    ks = jax.random.split(key, 4)
    flat = (cfg.image_size // 4) ** 2 * c2        # two 2x2 pools
    he = lambda key, shape, fan_in: (
        jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in))
    return {
        "conv1": {"w": he(ks[0], (k, k, cfg.channels, c1), k * k * cfg.channels),
                  "b": jnp.zeros((c1,), jnp.float32)},
        "conv2": {"w": he(ks[1], (k, k, c1, c2), k * k * c1),
                  "b": jnp.zeros((c2,), jnp.float32)},
        "fc1": {"w": he(ks[2], (flat, cfg.fc_width), flat),
                "b": jnp.zeros((cfg.fc_width,), jnp.float32)},
        "fc2": {"w": he(ks[3], (cfg.fc_width, cfg.num_classes), cfg.fc_width),
                "b": jnp.zeros((cfg.num_classes,), jnp.float32)},
    }


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def _maxpool2(x: jax.Array) -> jax.Array:
    # 2x2/2 pooling tiles exactly, so a reshape+max replaces reduce_window;
    # same forward values, but the backward avoids XLA:CPU's scalar
    # select-and-scatter path (~10x slower than this form's masked grad)
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def cnn_forward(params: Params, images: jax.Array) -> jax.Array:
    """images: (B, 28, 28, 1) -> logits (B, 10)."""
    x = images
    for name in ("conv1", "conv2"):
        p = params[name]
        x = jax.lax.conv_general_dilated(
            x, p["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        x = jax.nn.relu(x)
        x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_loss(params: Params, images: jax.Array,
             labels: jax.Array) -> Tuple[jax.Array, Dict]:
    """Categorical cross-entropy (paper §3.1)."""
    logits = cnn_forward(params, images)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = logz - gold
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return nll.mean(), {"acc": acc, "nll": nll.mean()}


def cnn_sample_losses(params: Params, images: jax.Array,
                      labels: jax.Array) -> jax.Array:
    """Per-sample loss — Eq. 7's l_i numerator terms (no gradient update)."""
    logits = cnn_forward(params, images)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return logz - gold
