"""Mamba-1 selective-state-space block (used by jamba's mamba layers).

Selective scan over time is chunked (outer lax.scan over time chunks with
``jax.checkpoint``) so training backprop stores per-chunk states, not
per-step — the same treatment as the RWKV6 scan.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, PARAM_DTYPE

SCAN_CHUNK = 256


def _dt_rank(cfg) -> int:
    return -(-cfg.d_model // 16)          # ceil(d_model / 16)


def init_mamba_layer(key: jax.Array, cfg) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    rk = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=PARAM_DTYPE), (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, di),
                                    PARAM_DTYPE) / math.sqrt(cfg.ssm_conv_width),
        "conv_b": jnp.zeros((di,), PARAM_DTYPE),
        "x_proj": dense_init(ks[2], di, rk + 2 * n),
        "dt_proj": dense_init(ks[3], rk, di),
        "dt_bias": jnp.full((di,), -4.6, PARAM_DTYPE),   # softplus^-1(0.01)
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), PARAM_DTYPE),
        "out_proj": dense_init(ks[4], di, d),
    }


def init_mamba_state(cfg, batch: int) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), jnp.float32),
        "h": jnp.zeros((batch, di, cfg.ssm_state_dim), jnp.float32),
    }


def _ssm_scan(xb: jax.Array, dt: jax.Array, bmat: jax.Array, cmat: jax.Array,
              a: jax.Array, h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Chunked selective scan.

    xb, dt: (B,T,Di); bmat, cmat: (B,T,N); a: (Di,N); h0: (B,Di,N).
    h_t = exp(dt_t a) h_{t-1} + dt_t * B_t ⊗ x_t;   y_t = h_t · C_t.
    """
    b, t, di = xb.shape
    n = bmat.shape[-1]
    c = SCAN_CHUNK if t % SCAN_CHUNK == 0 else t
    nc = t // c

    from repro.sharding.api import constrain

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dt32 = dt_t.astype(jnp.float32)
        da = jnp.exp(dt32[..., None] * a)                     # (B,Di,N)
        h = da * h + (dt32 * x_t.astype(jnp.float32))[..., None] \
            * b_t.astype(jnp.float32)[:, None, :]
        h = constrain(h, ("batch", "ff", None))
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y

    @jax.checkpoint
    def chunk(h, inp):
        return jax.lax.scan(step, h, inp)

    def outer(h, inp):
        return chunk(h, inp)

    # xs stay bf16 (HBM traffic /2); the state h is fp32 throughout.
    r = lambda z: constrain(
        z.reshape(b, nc, c, z.shape[-1]).transpose(1, 2, 0, 3),
        (None, None, "batch", "ff" if z.shape[-1] == di else None))
    hT, ys = jax.lax.scan(outer, h0, (r(xb), r(dt), r(bmat), r(cmat)))
    return ys.transpose(2, 0, 1, 3).reshape(b, t, di).astype(xb.dtype), hT


def mamba_apply(cfg, p: Params, x: jax.Array,
                state: Optional[Params]) -> Tuple[jax.Array, Params]:
    """x: (B,S,D).  S==1 with state => decode step; else train/prefill."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    rk = _dt_rank(cfg)
    cw = cfg.ssm_conv_width
    dt_ = x.dtype

    from repro.sharding.api import constrain
    xz = x @ p["in_proj"].astype(dt_)                    # (B,S,2Di)
    xz = constrain(xz, ("batch", None, "ff"))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, ("batch", None, "ff"))
    z = constrain(z, ("batch", None, "ff"))

    # causal depthwise conv, width cw
    if s == 1 and state is not None:
        hist = jnp.concatenate([state["conv"].astype(dt_), xi], axis=1)
        conv_in = hist                                   # (B,cw,Di)
        xc = jnp.einsum("bwd,wd->bd", conv_in, p["conv_w"].astype(dt_))
        xc = (xc + p["conv_b"].astype(dt_))[:, None, :]
        new_conv = hist[:, 1:, :].astype(jnp.float32)
    else:
        first = (jnp.zeros((b, cw - 1, di), dt_) if state is None
                 else state["conv"].astype(dt_))
        hist = jnp.concatenate([first, xi], axis=1)      # (B,S+cw-1,Di)
        # depthwise causal conv — no (B,S,cw,Di) materialization
        kernel = p["conv_w"].astype(dt_)[:, None, :]     # (cw, 1, Di)
        xc = jax.lax.conv_general_dilated(
            hist, kernel, (1,), "VALID",
            dimension_numbers=("NHC", "HIO", "NHC"),
            feature_group_count=di)
        xc = constrain(xc + p["conv_b"].astype(dt_), ("batch", None, "ff"))
        new_conv = hist[:, -(cw - 1):, :].astype(jnp.float32)
    xc = jax.nn.silu(xc)

    dbc = xc @ p["x_proj"].astype(dt_)                   # (B,S,rk+2N)
    dt_r, bmat, cmat = jnp.split(dbc, [rk, rk + n], axis=-1)
    delta = jax.nn.softplus(dt_r @ p["dt_proj"].astype(dt_)
                            + p["dt_bias"].astype(dt_))  # (B,S,Di)
    a = -jnp.exp(p["A_log"])                             # (Di,N)

    h0 = (jnp.zeros((b, di, n), jnp.float32) if state is None
          else state["h"])
    if s == 1 and state is not None:
        da = jnp.exp(delta[:, 0, :, None].astype(jnp.float32) * a)
        h = da * h0 + (delta[:, 0] * xc[:, 0]).astype(jnp.float32)[..., None] \
            * bmat[:, 0].astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))[:, None]
        hT = h
    else:
        y, hT = _ssm_scan(xc, delta, bmat, cmat, a, h0)
    y = y.astype(dt_) + xc * p["D"].astype(dt_)
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dt_)
    return out, {"conv": new_conv, "h": hT}
