"""Attention: GQA/MQA, RoPE, sliding-window, prefix-LM, KV-cache decode.

Training / prefill use a chunked flash-style softmax (``flash_attention``)
so the (S, S) score matrix is never materialised — peak is one
(B, H, q_chunk, kv_chunk) tile in fp32.  Decode is a single-query gather
over a slot-indexed cache that supports both full and ring (sliding-window)
layouts.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (Params, dense_init, rms_norm, rope,
                                 COMPUTE_DTYPE, PARAM_DTYPE)

NEG_INF = -1e30


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg, d: int) -> Params:
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, hq * dh),
         "wk": dense_init(ks[1], d, hkv * dh),
         "wv": dense_init(ks[2], d, hkv * dh),
         "wo": dense_init(ks[3], hq * dh, d)}
    if cfg.qk_norm:
        p["qn"] = jnp.zeros((dh,), PARAM_DTYPE)
        p["kn"] = jnp.zeros((dh,), PARAM_DTYPE)
    return p


def init_cross_attention(key: jax.Array, cfg, d: int) -> Params:
    return init_attention(key, cfg, d)


# --------------------------------------------------------------------------
# flash-style chunked attention (train / prefill)
# --------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    prefix_len: int = 0,
                    q_chunk: int = 256, kv_chunk: int = 1024) -> jax.Array:
    """q: (B,Sq,Hq,Dh); k,v: (B,Skv,Hkv,Dh); positions: (Sq,), (Skv,).

    Mask: kv allowed iff  (not causal) or kv_pos <= q_pos, further
    restricted by sliding ``window`` and relaxed for a bidirectional
    ``prefix_len`` (prefix-LM / PaliGemma).
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    if sq % qc or skv % kc:            # irregular sizes: single chunk
        qc, kc = sq, skv
    nq, nk = sq // qc, skv // kc

    qs = q.reshape(b, nq, qc, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)
    ks_ = k.reshape(b, nk, kc, hkv, dh).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kc, hkv, dh).transpose(1, 0, 3, 2, 4)
    qps = q_pos.reshape(nq, qc)
    kps = kv_pos.reshape(nk, kc)

    def q_body(_, q_in):
        qc_, qp = q_in                                # (b,hkv,g,qc,dh), (qc,)
        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, dh), jnp.float32)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            kc_, vc_, kp = kv_in
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc_, kc_,
                           preferred_element_type=jnp.float32) * scale
            ok = jnp.ones((qc, kc), bool)
            if causal:
                ok = kp[None, :] <= qp[:, None]
                if window:
                    ok &= (qp[:, None] - kp[None, :]) < window
                if prefix_len:
                    ok |= kp[None, :] < prefix_len
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = (acc * alpha[..., None]
                   + jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vc_.dtype), vc_,
                                preferred_element_type=jnp.float32))
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks_, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qs, qps))
    # outs: (nq, b, hkv, g, qc, dh) -> (b, sq, hq, dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, dh)
    return out


# --------------------------------------------------------------------------
# decode attention over a slot cache
# --------------------------------------------------------------------------

def make_kv_cache(batch: int, slots: int, hkv: int, dh: int,
                  dtype=COMPUTE_DTYPE) -> Params:
    return {
        "k": jnp.zeros((batch, slots, hkv, dh), dtype),
        "v": jnp.zeros((batch, slots, hkv, dh), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),   # absolute position/slot
        "idx": jnp.zeros((), jnp.int32),            # next absolute position
    }


def decode_attention(q: jax.Array, cache: Params, k_new: jax.Array,
                     v_new: jax.Array, *, window: int = 0,
                     prefix_len: int = 0) -> Tuple[jax.Array, Params]:
    """One-token attention.  q,k_new,v_new: (B,1,H*,Dh).  Ring-writes into
    the cache (slot = idx % slots) and attends over every valid slot."""
    b, _, hq, dh = q.shape
    slots = cache["k"].shape[1]
    hkv = cache["k"].shape[2]
    g = hq // hkv
    idx = cache["idx"]
    slot = idx % slots

    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], idx[None], slot, axis=0)

    scale = 1.0 / math.sqrt(dh)
    qh = q.reshape(b, hkv, g, dh)
    s = jnp.einsum("bhgd,bthd->bhgt", qh, k,
                   preferred_element_type=jnp.float32) * scale
    ok = (pos >= 0) & (pos <= idx)
    if window:
        ok &= (idx - pos) < window
    if prefix_len:
        ok |= (pos >= 0) & (pos < prefix_len)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq, dh).astype(q.dtype)
    return out, {"k": k, "v": v, "pos": pos, "idx": idx + 1}


# --------------------------------------------------------------------------
# full attention block (norm -> qkv -> rope -> attn -> out)
# --------------------------------------------------------------------------

def _project_qkv(cfg, p: Params, x: jax.Array, positions: jax.Array,
                 *, use_rope: bool = True):
    b, s, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, hq, dh)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, hkv, dh)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"])
        k = rms_norm(k, p["kn"])
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply_full(cfg, p: Params, x: jax.Array, positions: jax.Array, *,
                    causal: bool = True, window: int = 0, prefix_len: int = 0,
                    use_rope: bool = True,
                    return_kv: bool = False):
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = _project_qkv(cfg, p, x, positions, use_rope=use_rope)
    out = flash_attention(q, k, v, positions, positions, causal=causal,
                          window=window, prefix_len=prefix_len)
    b, s, _, _ = out.shape
    y = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    if return_kv:
        from repro.sharding.api import constrain_kv
        return y, (constrain_kv(k), constrain_kv(v))
    return y


def attn_apply_decode(cfg, p: Params, x: jax.Array, cache: Params, *,
                      window: int = 0, prefix_len: int = 0,
                      use_rope: bool = True):
    """Self-attention for one new token against the cache."""
    pos = cache["idx"][None]                       # (1,) current position
    q, k, v = _project_qkv(cfg, p, x, pos, use_rope=use_rope)
    out, cache = decode_attention(q, cache, k, v, window=window,
                                  prefix_len=prefix_len)
    b = x.shape[0]
    y = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return y, cache


def cross_attn_apply(cfg, p: Params, x: jax.Array,
                     enc_k: jax.Array, enc_v: jax.Array):
    """Cross-attention to precomputed encoder K/V (whisper decoder)."""
    b, s, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, hq, dh)
    skv = enc_k.shape[1]
    qp = jnp.arange(s)
    kp = jnp.arange(skv)
    out = flash_attention(q, enc_k.astype(dt), enc_v.astype(dt), qp, kp,
                          causal=False)
    return out.reshape(b, s, -1) @ p["wo"].astype(dt)


def encoder_kv(cfg, p: Params, enc: jax.Array):
    """Precompute cross-attention K/V from encoder states."""
    b, s, d = enc.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    dt = enc.dtype
    k = (enc @ p["wk"].astype(dt)).reshape(b, s, hkv, dh)
    v = (enc @ p["wv"].astype(dt)).reshape(b, s, hkv, dh)
    return k, v
