"""Sharded multi-seed sweep over (scheme x classes-per-client x
distribution x async scenario) — the paper's Figs. 6-9 evaluation grid
with error bars, plus the event-driven fleet axis (ISSUE 6).

  PYTHONPATH=src python -m repro.launch.sweep --fast --seeds 2
  PYTHONPATH=src python -m repro.launch.sweep --fast --seeds 3 \\
      --classes 9,6,2 --distributions uniform,extreme --out grid.csv
  PYTHONPATH=src python -m repro.launch.sweep --fast --seeds 2 \\
      --churn-rates 0,0.3 --staleness-lambdas 0,1 --agg-cadences 0,30

Each **cell** is a whole (scheme, classes_per_client, distribution,
seed) simulation; the async flags add a **scenario** axis — every
(churn rate x staleness lambda x aggregation cadence) combination runs
the full cell grid through the event-driven server
(``fl/async_server.py``) and lands in the same tidy CSV with the
streaming columns (active fleet size, stale-update fraction, effective
cohort size, rounds-behind histogram).  The all-defaults scenario is
the synchronous round barrier, bit-identical to a sweep with no async
flags at all.

The harness exploits the staged round pipeline (``fl/pipeline.py``) on
two axes:

- **seeds are vmapped**: all seeds of a cell group share one
  ``StageConfig`` (the jit-static), so their selection prefixes run as a
  single ``selection_prefix_seeds`` dispatch per round — one compiled
  program evaluates S seeds' probe/evaluate/select/deadline stages at
  once.  Training still runs per seed (cohorts differ), through the same
  ``finish_round`` the single-seed drivers use.
- **cell groups are distributed**: groups are placed round-robin over
  ``repro.sharding.api.sweep_devices()`` (the active mesh's devices, or
  all local devices) via ``jax.default_device`` — this spreads *memory*
  (each group's datasets and jit executables live on its device) but
  the in-process loop is synchronous, so wall-clock parallelism comes
  from worker *processes* (``--workers N``, spawn-based).  On a single
  CPU device with one worker this degrades to serial execution — the
  correctness baseline.
- **the client axis is meshed** (``--mesh clients=K``): inside the
  activated clients mesh every cell's *in-round* client axis is
  partitioned across the K devices — the seed-vmapped prefix dispatches
  as ``selection_prefix_seeds_sharded`` and the grouped trainer psums
  its FedAvg across shards.  The whole mesh is then ONE placement
  domain (``sweep_devices`` collapses to a single entry), and worker
  processes each rebuild the same mesh from the spec.

Execution knobs (engine, fused probe, overlap, mesh, server/churn/
staleness/cadence) all live on ONE ``RunConfig``
(``fl/runconfig.py``) shared with ``FLSimulation`` and
``launch/fl_sim.py`` — the scenario axis is just
``dataclasses.replace`` over that config.

Output: ONE tidy CSV, one row per (cell, scenario, round), with
per-seed metrics plus mean +/- std columns aggregated across the
group's seeds (constant within a (round, scheme, classes, distribution,
scenario) group) — directly plottable as the error-bar curves of
Figs. 6-8.  Byte/time columns come from the
``core/overhead.py``-reconciled accounting (Fig. 9).  Rows are emitted
in a deterministic order and with fixed float formatting, so a repeated
sweep is bitwise identical (tests/test_sweep.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import io
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import pipeline
from repro.fl.async_server import EventDrivenServer
from repro.fl.client import evaluate_accuracy_async
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig, add_run_arguments
from repro.ioutil import write_atomic
from repro.launch import faults
from repro.sharding.api import sweep_devices

SCHEMES = ("dcs", "ccs-fuzzy", "random")

# one row per (cell, scenario, round): cell identity + the async
# scenario coordinates + per-seed metrics + the across-seed aggregates
# (constant within a seed group).  agg_cadence_s reports 0 for "round
# period" (RunConfig's None) so the column stays numeric.
CSV_COLUMNS = (
    "round", "scheme", "seed", "classes_per_client", "distribution",
    "churn_rate", "staleness_lambda", "agg_cadence_s",
    "accuracy", "n_selected", "n_aggregated", "n_straggler",
    "n_active", "stale_frac", "n_effective", "rounds_behind_hist",
    "mean_eval_selected", "state_bytes", "upload_bytes", "state_time_s",
    "comm_time_s",
    "accuracy_mean", "accuracy_std", "n_selected_mean", "n_selected_std",
    "n_straggler_mean", "n_straggler_std",
)

_FMT = {"accuracy": "{:.6f}", "mean_eval_selected": "{:.4f}",
        "churn_rate": "{:.3f}", "staleness_lambda": "{:.4g}",
        "agg_cadence_s": "{:.6g}",
        "stale_frac": "{:.4f}", "n_effective": "{:.4f}",
        "state_bytes": "{:.6g}", "upload_bytes": "{:.6g}",
        "state_time_s": "{:.6g}", "comm_time_s": "{:.6g}",
        "accuracy_mean": "{:.6f}", "accuracy_std": "{:.6f}",
        "n_selected_mean": "{:.4f}", "n_selected_std": "{:.4f}",
        "n_straggler_mean": "{:.4f}", "n_straggler_std": "{:.4f}"}

# the key that identifies one seed group in the tidy output: a cell
# plus its async scenario coordinates
_GROUP_KEY = ("round", "scheme", "classes_per_client", "distribution",
              "churn_rate", "staleness_lambda", "agg_cadence_s")

# sweep cell group: every seed of one (scheme, classes, distribution)
Group = Tuple[str, int, str]


def fast_cell_config(scheme: str, classes_per_client: int,
                     distribution: str, seed: int) -> FLSimConfig:
    """CPU-budget profile per cell (mirrors launch/fl_sim.fast_config).

    Fewer classes/client concentrate per-class demand under the no-dup
    partition rule, so the source pool grows with non-iid-ness."""
    part = PartitionConfig(big_quantity=300, small_quantity=45,
                           classes_per_client=classes_per_client, seed=seed)
    return FLSimConfig(
        scheme=scheme, partition=part, local_epochs=1,
        samples_per_class=600 + (9 - classes_per_client) * 80,
        mobility=MobilityConfig(distribution=distribution, seed=seed),
        seed=seed)


def paper_cell_config(scheme: str, classes_per_client: int,
                      distribution: str, seed: int) -> FLSimConfig:
    """Table 3 profile (expensive on CPU)."""
    part = PartitionConfig(classes_per_client=classes_per_client, seed=seed)
    return FLSimConfig(
        scheme=scheme, partition=part, local_epochs=30, deadline_s=20.0,
        mobility=MobilityConfig(distribution=distribution, seed=seed),
        seed=seed)


ConfigFn = Callable[[str, int, str, int], FLSimConfig]


def run_seed_group(scheme: str, classes_per_client: int, distribution: str,
                   seeds: Sequence[int], rounds: int,
                   cfg_fn: ConfigFn = fast_cell_config,
                   vmap_prefix: bool = True,
                   overlap: Optional[bool] = None,
                   run: Optional[RunConfig] = None,
                   checkpoint_dir: Optional[str] = None,
                   checkpoint_every: int = 1,
                   resume: bool = False) -> List[Dict]:
    """Run every seed of one cell group for ``rounds`` rounds.

    ``run`` is the shared execution profile (``RunConfig``): the sync
    drivers complete each round through ``FLSimulation``; any async knob
    routes training and aggregation through the cell's
    ``EventDrivenServer`` instead — the seed-vmapped prefix dispatch is
    identical either way (the event axis only changes what happens after
    the cohort gather).

    When the seeds share a ``StageConfig`` (they do by construction —
    only arrays differ), their selection prefixes are evaluated in ONE
    vmapped dispatch per round; per-seed training and aggregation then
    complete each round through the driver's ``finish_round``.

    ``overlap`` (default: the run config's ``overlap_rounds``) is the
    round-ahead scheduler: the prefix is pure in ``(statics, params,
    rnd, keys)`` and the per-seed params become device futures the
    moment the trainers are enqueued, so round r+1's (vmapped)
    selection dispatch is issued right after round r's training —
    before round r's accuracy metrics are read.  The vmapped dispatch
    then runs with ``donate_argnums`` on the seed-stacked params (a
    fresh (S, ...) stack every round).  Rows are bit-identical to the
    serial schedule — same ops, same order, earlier enqueue.

    Preemption safety (ISSUE 10): with ``checkpoint_dir`` the whole seed
    group snapshots atomically every ``checkpoint_every`` rounds (every
    seed's driver state in one ``RoundCheckpointer`` entry, plus the
    rows emitted so far); ``resume=True`` restores the latest good
    snapshot so a killed group replays only its unfinished rounds —
    bit-identically."""
    run = (run if run is not None else RunConfig()).resolved()
    if overlap is None:
        overlap = run.overlap_rounds
    sims = [FLSimulation(cfg_fn(scheme, classes_per_client, distribution,
                                seed), run=run) for seed in seeds]
    if not sims:
        return []
    drivers = [EventDrivenServer(sim) if run.server == "event" else sim
               for sim in sims]
    cfg0 = sims[0].stage_cfg
    use_vmap = (vmap_prefix and len(sims) > 1
                and all(s.stage_cfg == cfg0 for s in sims))
    stacked_st = (pipeline.stack_statics([s.statics for s in sims])
                  if use_vmap else None)
    sel_keys = jnp.stack([s.key for s in sims])
    net_keys = jnp.stack([s.net_key for s in sims])
    mesh = pipeline.active_client_mesh()

    def dispatch(r: int) -> List[Dict]:
        """Enqueue round ``r``'s selection prefixes; returns per-seed
        state dicts (device futures — nothing blocks here)."""
        if not use_vmap:
            return [sim.selection_state(r) for sim in sims]
        params = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[s.params for s in sims])
        if mesh is not None:
            outs = pipeline.selection_prefix_seeds_sharded(
                stacked_st, params, jnp.int32(r), sel_keys, net_keys,
                cfg=cfg0, mesh=mesh)
        else:
            outs = pipeline.selection_prefix_seeds_donated(
                stacked_st, params, jnp.int32(r), sel_keys, net_keys,
                cfg=cfg0)
        return [jax.tree.map(lambda x, i=i: x[i], outs)
                for i in range(len(sims))]

    def meta(seed: int, row: Dict) -> Dict:
        return {"scheme": scheme, "seed": seed,
                "classes_per_client": classes_per_client,
                "distribution": distribution,
                "churn_rate": run.churn_rate,
                "staleness_lambda": run.staleness_lambda,
                "agg_cadence_s": (run.agg_cadence_s
                                  if run.agg_cadence_s is not None
                                  else 0.0),
                **row}

    ckpt = None
    if checkpoint_dir:
        from repro.train.checkpoint import RoundCheckpointer
        ckpt = RoundCheckpointer(checkpoint_dir, every=checkpoint_every)
    rows: List[Dict] = []
    start = 0
    if resume and ckpt is not None:
        got = ckpt.latest_good()
        if got is not None:
            rnd, state, extra = got
            for drv, st in zip(drivers, state["seeds"]):
                drv.restore_state(st, extra)
            rows = [dict(row) for row in extra.get("rows", [])]
            start = rnd + 1
    lead = jax.process_index() == 0
    states = None
    for r in range(start, rounds):
        if states is None:
            states = dispatch(r)
        nxt = None
        if overlap:
            # the device_get fence also surfaces elect_overflow: any
            # flagged seed re-runs its prefix through the dense gather
            # before training, keeping windowed masks bit-identical
            hosts = [sim.resolve_elect_overflow(r, jax.device_get(s))
                     for sim, s in zip(sims, states)]
            for drv, host in zip(drivers, hosts):    # train dispatch
                drv._dispatch_training(r, host)
            pend = [evaluate_accuracy_async(sim._eval_params(),
                                            sim.test_images,
                                            sim.test_labels, batch=256)
                    for sim in sims]
            if r + 1 < rounds:                       # round-ahead
                nxt = dispatch(r + 1)
            for seed, drv, host, (acc, nt) in zip(seeds, drivers, hosts,
                                                  pend):
                rows.append(meta(seed, drv._round_row(r, host, acc, nt)))
        else:
            for seed, drv, state in zip(seeds, drivers, states):
                rows.append(meta(seed, drv.finish_round(r, state)))
        states = nxt
        if ckpt is not None and lead and ckpt.due(r):
            ckpt.save_round(
                r, {"seeds": [drv.capture_state() for drv in drivers]},
                extra={"rows": rows, "next_round": r + 1})
            faults.fire("checkpoint-saved", round=r)
        faults.fire("round-done", round=r)
    return rows


def aggregate_rows(rows: List[Dict]) -> List[Dict]:
    """Attach across-seed mean/std columns to every per-seed row (tidy:
    the aggregate is repeated within its (round, scheme, classes,
    distribution, scenario) group)."""
    groups: Dict[Tuple, List[Dict]] = {}
    for row in rows:
        # .get: rows from older callers may lack the scenario columns
        key = tuple(row.get(k) for k in _GROUP_KEY)
        groups.setdefault(key, []).append(row)
    out = []
    for row in rows:
        grp = groups[tuple(row.get(k) for k in _GROUP_KEY)]
        agg = {}
        for metric in ("accuracy", "n_selected", "n_straggler"):
            vals = np.asarray([g[metric] for g in grp], np.float64)
            agg[f"{metric}_mean"] = float(vals.mean())
            # sample std (ddof=1): the 2-3 seeds CI runs are a sample of
            # the seed distribution, and ddof=0 would understate the
            # error bars by ~30% at n=2
            agg[f"{metric}_std"] = float(vals.std(ddof=1)) \
                if len(vals) > 1 else 0.0
        out.append({**row, **agg})
    return out


def rows_to_csv(rows: List[Dict]) -> str:
    """Deterministic tidy CSV: fixed column order, fixed float formats,
    rows sorted by (scheme, classes, distribution, scenario, seed,
    round)."""
    buf = io.StringIO()
    buf.write(",".join(CSV_COLUMNS) + "\n")
    for row in sorted(rows, key=lambda r: (
            r["scheme"], r["classes_per_client"], r["distribution"],
            r["churn_rate"], r["staleness_lambda"], r["agg_cadence_s"],
            r["seed"], r["round"])):
        cells = []
        for col in CSV_COLUMNS:
            v = row[col]
            cells.append(_FMT[col].format(v) if col in _FMT else str(v))
        buf.write(",".join(cells) + "\n")
    return buf.getvalue()


# typed CSV parse: the resume path reads the sweep's own output back
_INT_COLS = {"round", "seed", "classes_per_client", "n_selected",
             "n_aggregated", "n_straggler", "n_active"}
_STR_COLS = {"scheme", "distribution", "rounds_behind_hist"}


def parse_csv_rows(text: str) -> Optional[List[Dict]]:
    """Parse a ``rows_to_csv`` artifact back into typed rows.

    Returns ``None`` when the header is not this sweep's schema (a
    foreign or incompatible file — the caller warns and starts fresh).
    Rows that fail to parse (a torn tail from a non-atomic writer, short
    or malformed lines) are dropped with a warning: their group simply
    reruns.  Because every float column re-formats idempotently under
    ``_FMT`` (parse(format(x)) == parse-stable), rows that survive a
    parse round-trip re-emit byte-identically."""
    import warnings
    lines = text.splitlines()
    if not lines or lines[0] != ",".join(CSV_COLUMNS):
        return None
    rows: List[Dict] = []
    dropped = 0
    for ln in lines[1:]:
        if not ln:
            continue
        cells = ln.split(",")
        if len(cells) != len(CSV_COLUMNS):
            dropped += 1
            continue
        try:
            row: Dict = {}
            for col, cell in zip(CSV_COLUMNS, cells):
                if col in _STR_COLS:
                    row[col] = cell
                elif col in _INT_COLS:
                    row[col] = int(cell)
                else:
                    row[col] = float(cell)
        except ValueError:
            dropped += 1
            continue
        rows.append(row)
    if dropped:
        warnings.warn(f"dropped {dropped} unparsable row(s) from the "
                      f"partial sweep CSV (torn tail); their groups "
                      f"will rerun", RuntimeWarning)
    return rows


def _scenario_key(run: RunConfig) -> Tuple[str, str, str]:
    """The async scenario coordinates as their *formatted* CSV strings —
    comparing formatted values makes job-vs-CSV matching immune to float
    parse/format wobble."""
    return (_FMT["churn_rate"].format(run.churn_rate),
            _FMT["staleness_lambda"].format(run.staleness_lambda),
            _FMT["agg_cadence_s"].format(run.agg_cadence_s
                                         if run.agg_cadence_s is not None
                                         else 0.0))


def _job_key(scheme: str, classes: int, dist: str,
             run: RunConfig) -> Tuple:
    return (scheme, int(classes), dist) + _scenario_key(run)


def _row_job_key(row: Dict) -> Tuple:
    return (row["scheme"], int(row["classes_per_client"]),
            row["distribution"],
            _FMT["churn_rate"].format(row["churn_rate"]),
            _FMT["staleness_lambda"].format(row["staleness_lambda"]),
            _FMT["agg_cadence_s"].format(row["agg_cadence_s"]))


def _group_ckpt_dir(checkpoint_dir: str, scheme: str, classes: int,
                    dist: str, run: RunConfig) -> str:
    """A deterministic per-(cell, scenario) checkpoint subdirectory —
    stable across the killed run and its resume."""
    import os
    slug = "_".join(str(p) for p in
                    _job_key(scheme, classes, dist, run)).replace(".", "p")
    return os.path.join(checkpoint_dir, slug)


def completed_job_rows(parsed: Optional[List[Dict]],
                       jobs: Sequence[Tuple[Group, RunConfig]],
                       seeds: Sequence[int],
                       rounds: int) -> Dict[Tuple, List[Dict]]:
    """Map each fully completed job (every (seed, round) row present in
    the partial CSV) to its parsed rows — those groups are skipped on
    resume and their rows pass through to the final CSV verbatim."""
    if not parsed:
        return {}
    by_job: Dict[Tuple, List[Dict]] = {}
    for row in parsed:
        by_job.setdefault(_row_job_key(row), []).append(row)
    want = {(int(s), r) for s in seeds for r in range(rounds)}
    out: Dict[Tuple, List[Dict]] = {}
    for (group, run) in jobs:
        key = _job_key(*group, run)
        got = [row for row in by_job.get(key, [])
               if (row["seed"], row["round"]) in want]
        if {(row["seed"], row["round"]) for row in got} >= want:
            out[key] = got
    return out


def _run_group_worker(args: Tuple) -> List[Dict]:
    """Top-level (picklable) worker: one cell group, serial in-process.
    ``mesh_spec`` (a ``--mesh`` string; Mesh objects don't pickle)
    rebuilds the client mesh inside the worker's own jax runtime; the
    frozen ``RunConfig`` pickles by value."""
    scheme, classes, dist, seeds, rounds, cfg_fn, vmap_prefix, \
        mesh_spec, overlap, run, cache_dir, ckpt_dir, ckpt_every, \
        resume = args
    from repro.launch.cache import enable_jit_cache
    from repro.launch.mesh import client_mesh_context
    with client_mesh_context(mesh_spec):
        # sibling workers retrace identical executables; the shared
        # persistent cache lets one worker's compile serve the rest
        enable_jit_cache(cache_dir)
        return run_seed_group(scheme, classes, dist, seeds, rounds,
                              cfg_fn=cfg_fn, vmap_prefix=vmap_prefix,
                              overlap=overlap, run=run,
                              checkpoint_dir=ckpt_dir,
                              checkpoint_every=ckpt_every, resume=resume)


def sweep(schemes: Sequence[str], classes_list: Sequence[int],
          distributions: Sequence[str], seeds: Sequence[int], rounds: int,
          cfg_fn: ConfigFn = fast_cell_config, vmap_prefix: bool = True,
          workers: int = 1, mesh_spec: Optional[str] = None,
          overlap: Optional[bool] = None,
          runs: Optional[Sequence[RunConfig]] = None,
          cache_dir: Optional[str] = None,
          log: Optional[Callable[[str], None]] = None,
          out_path: Optional[str] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 1,
          resume: bool = False) -> List[Dict]:
    """Run the full grid — every cell under every async scenario — and
    return aggregated tidy rows.

    ``runs`` is the scenario axis: one ``RunConfig`` per (churn rate x
    staleness lambda x aggregation cadence) combination (default: the
    single all-defaults sync scenario).  Cell-x-scenario groups are
    placed round-robin over ``sweep_devices()`` (serial fallback on one
    device; a clients mesh is one placement domain); ``workers > 1``
    additionally fans groups out over spawn-based processes (each worker
    owns its device runtime, so the device placement is left to the
    workers; ``cfg_fn`` crosses the process boundary by reference, so it
    must be a module-level function — a closure fails loudly at
    submission, never silently switching profiles).  ``mesh_spec``
    crosses as the ``--mesh`` string and is activated inside each worker
    (the parent's forced-device env is inherited by the spawned
    children).

    Preemption safety (ISSUE 10): with ``checkpoint_dir`` each group
    snapshots per round under its own subdirectory and — when
    ``out_path`` is set — the partial grid CSV is atomically rewritten
    after every finished group.  ``resume=True`` reads ``out_path``
    back: fully completed (cell, scenario) groups are recognized from
    their rows and skipped (their rows pass through verbatim; the
    ``_FMT`` formats are parse/format idempotent, so they re-emit
    byte-identically), in-flight groups restart from their round
    checkpoints, and the final CSV is byte-identical to an
    uninterrupted run's."""
    log = log or (lambda s: None)
    runs = tuple(runs) if runs else (RunConfig().resolved(),)
    jobs: List[Tuple[Group, RunConfig]] = [
        ((s, c, d), run) for run in runs for s in schemes
        for c in classes_list for d in distributions]

    done: Dict[Tuple, List[Dict]] = {}
    if resume and out_path:
        import os
        if os.path.exists(out_path):
            parsed = parse_csv_rows(open(out_path).read())
            if parsed is None:
                import warnings
                warnings.warn(
                    f"{out_path} is not a sweep CSV of this schema — "
                    f"ignoring it and rerunning the full grid",
                    RuntimeWarning)
            else:
                done = completed_job_rows(parsed, jobs, seeds, rounds)
    done_rows = [row for got in done.values() for row in got]
    lead = jax.process_index() == 0

    def group_dir(scheme, classes, dist, run):
        if not checkpoint_dir:
            return None
        return _group_ckpt_dir(checkpoint_dir, scheme, classes, dist, run)

    def clear_group_ckpt(scheme, classes, dist, run):
        d = group_dir(scheme, classes, dist, run)
        if d is not None and lead:
            from repro.train.checkpoint import RoundCheckpointer
            RoundCheckpointer(d).clear()

    def finish_group(index, group, run, fresh_rows):
        """After each completed group: atomically rewrite the partial
        grid CSV (the group's rows become durable), drop its
        now-redundant round checkpoints, then announce the chaos hook.
        A kill anywhere in this sequence resumes cleanly — worst case
        (before the CSV lands) the group reruns from its checkpoints."""
        if out_path and lead:
            write_atomic(out_path,
                         rows_to_csv(aggregate_rows(fresh_rows)
                                     + done_rows))
        clear_group_ckpt(*group, run)
        faults.fire("group-done", index=index)

    todo = [(i, group, run) for i, (group, run) in enumerate(jobs)
            if _job_key(*group, run) not in done]
    for key in done:
        log(f"[sweep] resume: skipping completed group "
            f"{'/'.join(str(p) for p in key)}")
    # a completed group's checkpoints are stale — drop them so a later
    # corruption there can never shadow the CSV's finished rows
    for i, (group, run) in enumerate(jobs):
        if _job_key(*group, run) in done:
            clear_group_ckpt(*group, run)

    rows: List[Dict] = []
    if workers > 1:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor
        work = [(s, c, d, tuple(seeds), rounds, cfg_fn, vmap_prefix,
                 mesh_spec, overlap, run, cache_dir,
                 group_dir(s, c, d, run), checkpoint_every, resume)
                for _, (s, c, d), run in todo]
        with ProcessPoolExecutor(
                max_workers=workers,
                mp_context=mp.get_context("spawn")) as pool:
            for (i, (s, c, d), run), got in zip(
                    todo, pool.map(_run_group_worker, work)):
                log(f"[sweep] {s} classes={c} {d} "
                    f"churn={run.churn_rate} lam={run.staleness_lambda}: "
                    f"{len(got)} rows")
                rows.extend(got)
                finish_group(i, (s, c, d), run, rows)
        return aggregate_rows(rows) + done_rows

    devices = sweep_devices()
    for i, (scheme, classes, dist), run in todo:
        dev = devices[i % len(devices)]
        t0 = time.time()
        with jax.default_device(dev):
            got = run_seed_group(scheme, classes, dist, seeds, rounds,
                                 cfg_fn=cfg_fn, vmap_prefix=vmap_prefix,
                                 overlap=overlap, run=run,
                                 checkpoint_dir=group_dir(scheme, classes,
                                                          dist, run),
                                 checkpoint_every=checkpoint_every,
                                 resume=resume)
        rows.extend(got)
        finish_group(i, (scheme, classes, dist), run, rows)
        accs = [r["accuracy"] for r in got if r["round"] == rounds - 1]
        log(f"[sweep] {scheme} classes={classes} {dist} "
            f"churn={run.churn_rate} lam={run.staleness_lambda} "
            f"cadence={run.agg_cadence_s or 0} on {dev}: "
            f"final acc {np.mean(accs):.3f} +/- {np.std(accs):.3f} "
            f"({len(seeds)} seeds, {time.time() - t0:.0f}s)")
    return aggregate_rows(rows) + done_rows


def scenario_runs(base: RunConfig, churn_rates: Sequence[float],
                  staleness_lambdas: Sequence[float],
                  agg_cadences: Sequence[float]) -> List[RunConfig]:
    """The async scenario axis: every (churn x lambda x cadence) combo
    as a ``RunConfig`` derived from ``base``.  A lambda of 0 keeps the
    hard-deadline "drop" policy (weighting with lambda=0 would train
    stragglers at full weight — a different policy than the sync
    baseline); cadence 0 means "the round period"."""
    out = []
    for churn in churn_rates:
        for lam in staleness_lambdas:
            for cad in agg_cadences:
                out.append(dataclasses.replace(
                    base, churn_rate=churn,
                    staleness="weighted" if lam > 0 else base.staleness,
                    staleness_lambda=lam,
                    agg_cadence_s=cad if cad > 0 else None).resolved())
    return out


def _float_list(text: str) -> Tuple[float, ...]:
    return tuple(float(x) for x in text.split(","))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--schemes", default="all",
                    help="comma list or 'all' (dcs,ccs-fuzzy,random)")
    ap.add_argument("--classes", default="9",
                    help="comma list of classes-per-client (Fig. 8: 9,6,2)")
    ap.add_argument("--distributions", default="uniform",
                    help="comma list (Fig. 7: uniform,extreme)")
    ap.add_argument("--seeds", type=int, default=2,
                    help="number of seeds per cell (0..N-1)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--fast", action="store_true",
                    help="CPU-budget profile (the default)")
    ap.add_argument("--paper-profile", action="store_true",
                    help="Table 3 profile (expensive on CPU)")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker processes for cell groups (1 = in-process)")
    ap.add_argument("--no-vmap", action="store_true",
                    help="disable the seed-vmapped selection prefix")
    # the shared RunConfig flags (mesh / fused probe / overlap / server /
    # single-scenario async knobs) — fl/runconfig.py
    add_run_arguments(ap)
    # the *plural* scenario-axis flags: each adds a grid dimension
    ap.add_argument("--churn-rates", type=_float_list, default=None,
                    help="comma list of coverage-window churn rates "
                         "(scenario axis; e.g. 0,0.3)")
    ap.add_argument("--staleness-lambdas", type=_float_list, default=None,
                    help="comma list of staleness decay lambdas "
                         "(scenario axis; 0 = hard-deadline drop)")
    ap.add_argument("--agg-cadences", type=_float_list, default=None,
                    help="comma list of aggregation cadences in simulated "
                         "seconds (scenario axis; 0 = the round period)")
    from repro.launch.cache import add_cache_arguments, resolve_cache_dir
    from repro.launch.multihost import (add_multihost_arguments,
                                        multihost_from_args, should_spawn,
                                        spawn_multihost)
    add_multihost_arguments(ap)
    add_cache_arguments(ap)
    ap.add_argument("--out", default="sweep.csv")
    args = ap.parse_args(argv)

    # checkpoints default to a dotdir beside the output (mirrors the jit
    # cache); set BEFORE RunConfig.from_args so --resume validates
    if args.checkpoint_dir is None:
        args.checkpoint_dir = args.out + ".ckpt"

    if args.fast and args.paper_profile:
        ap.error("--fast and --paper-profile are mutually exclusive")
    if args.multihost > 1 and args.workers > 1:
        ap.error("--multihost and --workers are mutually exclusive (a "
                 "multi-process mesh is already one placement domain)")
    if should_spawn(args):
        import sys
        return spawn_multihost("repro.launch.sweep",
                               list(argv) if argv is not None
                               else sys.argv[1:], args.multihost)
    if args.seeds < 1:
        ap.error("--seeds must be >= 1")
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")
    schemes = SCHEMES if args.schemes == "all" \
        else tuple(args.schemes.split(","))
    for s in schemes:
        if s not in SCHEMES:
            ap.error(f"unknown scheme {s!r} (known: {SCHEMES})")
    classes_list = tuple(int(c) for c in args.classes.split(","))
    distributions = tuple(args.distributions.split(","))
    cfg_fn = paper_cell_config if args.paper_profile else fast_cell_config

    full_run = RunConfig.from_args(args)
    # the grid drives rounds itself — per-group checkpointing is the
    # sweep's own (run_seed_group), not the per-sim RunConfig contract
    base_run = dataclasses.replace(full_run, checkpoint_dir=None,
                                   checkpoint_every=1, resume=False)
    if (args.churn_rates is None and args.staleness_lambdas is None
            and args.agg_cadences is None):
        runs = [base_run]
    else:
        runs = scenario_runs(base_run,
                             args.churn_rates or (base_run.churn_rate,),
                             args.staleness_lambdas
                             or (base_run.staleness_lambda,),
                             args.agg_cadences
                             or (base_run.agg_cadence_s or 0.0,))

    t0 = time.time()
    cache_dir = resolve_cache_dir(args.jit_cache_dir, args.out)
    from repro.launch.cache import enable_jit_cache
    from repro.launch.mesh import client_mesh_context
    with client_mesh_context(args.mesh,
                             multihost=multihost_from_args(args)) as mesh:
        is_lead = jax.process_index() == 0
        if args.workers <= 1:
            enable_jit_cache(cache_dir)   # workers enable their own
        if mesh is not None and is_lead:
            print(f"[sweep] client mesh: {dict(mesh.shape)} over "
                  f"{mesh.devices.size} devices"
                  + (f" / {jax.process_count()} processes"
                     if jax.process_count() > 1 else ""), flush=True)
        rows = sweep(schemes, classes_list, distributions,
                     seeds=range(args.seeds), rounds=args.rounds,
                     cfg_fn=cfg_fn, vmap_prefix=not args.no_vmap,
                     workers=args.workers, mesh_spec=args.mesh,
                     runs=runs, cache_dir=cache_dir,
                     log=(lambda s: print(s, flush=True)) if is_lead
                     else (lambda s: None),
                     out_path=args.out,
                     checkpoint_dir=full_run.checkpoint_dir,
                     checkpoint_every=full_run.checkpoint_every,
                     resume=full_run.resume)
    csv_text = rows_to_csv(rows)
    if is_lead:                  # one writer in a multi-process launch
        write_atomic(args.out, csv_text)
        print(f"[sweep] wrote {len(rows)} rows "
              f"({len(schemes)}x{len(classes_list)}x{len(distributions)} "
              f"cells x {len(runs)} scenarios x {args.seeds} seeds x "
              f"{args.rounds} rounds) to {args.out} in "
              f"{time.time() - t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
