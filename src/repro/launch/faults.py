"""Deterministic fault injection (ISSUE 10): replayable failure
schedules for the chaos suite and CI.

A fault *plan* is a semicolon-separated list of directives in the
``REPRO_FAULTS`` environment variable (or passed explicitly to
``parse_plan``)::

    REPRO_FAULTS="sigkill@checkpoint-saved:round=2;exit@mh-child-start:rank=1"

Each directive is ``ACTION@EVENT[:k=v,...]``.  Instrumented code calls
``fire(event, **context)`` at well-known points; when a directive's
event matches and every ``k=v`` parameter matches the fired context
(string-compared), its action executes:

- ``sigkill`` — ``os.kill(os.getpid(), SIGKILL)``: the hard death a
  preempted worker or OOM-killed sweep process sees.  No cleanup, no
  ``atexit``, no flushing — exactly what the atomic-write + checkpoint
  recovery contract must survive.
- ``exit[=code]`` — ``os._exit(code)`` (default 3): an abrupt but
  "clean-exit-code" death, used to kill one multihost peer so the
  parent's reaping logic is exercised.

Non-terminal behaviour switches use ``active(action, event, **ctx)``
instead — e.g. ``overflow@resume`` makes a restored FLSimulation clamp
``elect_capacity`` to 1 so every round takes the ``elect_overflow``
dense-recovery path after resume.

Well-known events (grep for ``faults.fire``):

=====================  =====================================  ==========
event                  fired by                               params
=====================  =====================================  ==========
``round-done``         FLSimulation / EventDrivenServer run   ``round``
``checkpoint-saved``   the same, after a round snapshot       ``round``
``group-done``         sweep, after each (cell, seed-group)   ``index``
``mh-child-start``     mesh ctx in a multihost child          ``rank``
``resume``             drivers, via ``active`` on restore     --
=====================  =====================================  ==========

Everything here is jax-free and import-cheap: the plan is re-read from
the environment on every ``fire``/``active`` so subprocesses inherit
schedules without any setup, and ``main()`` exposes the file-corruption
helpers (``truncate``, ``flipbyte``) to CI shell steps.
"""
from __future__ import annotations

import os
import signal
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

ENV_VAR = "REPRO_FAULTS"

_TERMINAL_ACTIONS = ("sigkill", "exit")


@dataclass(frozen=True)
class FaultDirective:
    action: str            # "sigkill" | "exit" | a behaviour switch name
    event: str             # event name matched against fire()/active()
    params: Tuple[Tuple[str, str], ...] = ()   # ((key, value), ...)
    code: int = 3          # exit code for action == "exit"

    def matches(self, event: str, ctx: Dict[str, object]) -> bool:
        if event != self.event:
            return False
        return all(k in ctx and str(ctx[k]) == v for k, v in self.params)


def parse_plan(spec: Optional[str] = None) -> List[FaultDirective]:
    """Parse a fault plan string (default: ``$REPRO_FAULTS``)."""
    if spec is None:
        spec = os.environ.get(ENV_VAR, "")
    out: List[FaultDirective] = []
    for raw in spec.split(";"):
        raw = raw.strip()
        if not raw:
            continue
        if "@" not in raw:
            raise ValueError(
                f"bad fault directive {raw!r}: want ACTION@EVENT[:k=v,...]")
        action, rest = raw.split("@", 1)
        action = action.strip()
        code = 3
        if action.startswith("exit="):
            code = int(action[5:])
            action = "exit"
        event, _, params_s = rest.partition(":")
        params: List[Tuple[str, str]] = []
        if params_s:
            for kv in params_s.split(","):
                if "=" not in kv:
                    raise ValueError(
                        f"bad fault parameter {kv!r} in {raw!r}")
                k, v = kv.split("=", 1)
                params.append((k.strip(), v.strip()))
        out.append(FaultDirective(action=action, event=event.strip(),
                                  params=tuple(params), code=code))
    return out


def fire(event: str, **ctx: object) -> None:
    """Announce an instrumentation point; execute any matching terminal
    directive (sigkill / exit).  A no-op when no plan is set."""
    spec = os.environ.get(ENV_VAR, "")
    if not spec:
        return
    for d in parse_plan(spec):
        if d.action not in _TERMINAL_ACTIONS or not d.matches(event, ctx):
            continue
        sys.stderr.write(
            f"[repro.faults] injecting {d.action} at {event} "
            f"({', '.join(f'{k}={v}' for k, v in ctx.items())})\n")
        sys.stderr.flush()
        if d.action == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        os._exit(d.code)


def active(action: str, event: str, **ctx: object) -> bool:
    """True when a non-terminal behaviour switch (e.g. ``overflow``)
    matches this event — the caller implements the behaviour."""
    spec = os.environ.get(ENV_VAR, "")
    if not spec:
        return False
    return any(d.action == action and d.matches(event, ctx)
               for d in parse_plan(spec))


# -- file corruption helpers (torn-artifact injection) ------------------

def truncate_file(path: str, keep_bytes: int) -> None:
    """Truncate ``path`` to its first ``keep_bytes`` bytes — a torn
    write as left by a crash on a non-atomic writer."""
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)


def flip_byte(path: str, offset: int) -> None:
    """XOR the byte at ``offset`` with 0xFF — silent media corruption
    that only a checksum catches."""
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        if len(b) != 1:
            raise ValueError(f"{path}: offset {offset} out of range")
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def main(argv: Optional[List[str]] = None) -> int:
    """CLI for CI shell steps::

        python -m repro.launch.faults truncate FILE KEEP_BYTES
        python -m repro.launch.faults flipbyte FILE OFFSET
        python -m repro.launch.faults check 'PLAN'   # parse-validate
    """
    args = list(sys.argv[1:] if argv is None else argv)
    if not args:
        print(main.__doc__)
        return 2
    cmd = args[0]
    if cmd == "truncate":
        truncate_file(args[1], int(args[2]))
        return 0
    if cmd == "flipbyte":
        flip_byte(args[1], int(args[2]))
        return 0
    if cmd == "check":
        for d in parse_plan(args[1] if len(args) > 1 else None):
            print(d)
        return 0
    print(f"unknown command {cmd!r}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
