from repro.launch.mesh import (CHIPS_PER_POD, CLIENT_AXIS, HBM_BW,
                               HBM_BYTES_PER_CHIP, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16, ensure_host_device_count,
                               make_clients_mesh, make_debug_mesh,
                               make_production_mesh)

__all__ = [
    "CHIPS_PER_POD", "CLIENT_AXIS", "HBM_BW", "HBM_BYTES_PER_CHIP",
    "ICI_BW_PER_LINK", "PEAK_FLOPS_BF16", "ensure_host_device_count",
    "make_clients_mesh", "make_debug_mesh", "make_production_mesh",
]
