"""Multi-process launch plumbing (jax-free: argparse + subprocess only).

``--multihost P`` runs a launcher as ``P`` cooperating jax processes —
on CPU this *emulates* a multi-host fleet by spawning ``P`` copies of
the same command wired to one local coordinator, each owning
``K / P`` of the ``clients`` mesh devices; on a real multi-host slice
the same flags describe the actual coordinator/process topology.

The spawn protocol is self-re-execution: the parent parses
``--multihost P``, picks a free coordinator port, and re-launches its
own ``python -m <module> <argv>`` ``P`` times with the hidden
``--_mh-coord/--_mh-procs/--_mh-proc-id`` flags appended; a child sees
``--_mh-proc-id`` and initializes ``jax.distributed`` instead of
re-spawning.  Output-writing call sites gate on ``jax.process_index()
== 0``.  This module stays importable before jax so launchers can parse
flags without initializing any backend.
"""
from __future__ import annotations

import socket
import subprocess
import sys
from typing import List, Optional, Sequence, Tuple


def add_multihost_arguments(ap) -> None:
    """Install ``--multihost`` plus the hidden child-process flags."""
    ap.add_argument("--multihost", type=int, default=0, metavar="P",
                    help="run as P cooperating jax processes (CPU: "
                         "emulated via spawned local processes); the "
                         "mesh's clients=K axis spans all of them "
                         "(K %% P == 0)")
    ap.add_argument("--_mh-coord", default=None, help=_SUPPRESS())
    ap.add_argument("--_mh-procs", type=int, default=None,
                    help=_SUPPRESS())
    ap.add_argument("--_mh-proc-id", type=int, default=None,
                    help=_SUPPRESS())


def _SUPPRESS() -> str:
    import argparse
    return argparse.SUPPRESS


def multihost_from_args(args) -> Optional[Tuple[str, int, int]]:
    """The child-process distributed-init triple ``(coordinator,
    num_processes, process_id)``, or None outside a spawned child."""
    pid = getattr(args, "_mh_proc_id", None)
    if pid is None:
        return None
    return (args._mh_coord, int(args._mh_procs), int(pid))


def should_spawn(args) -> bool:
    """True in the parent process of a ``--multihost P`` launch (P > 1
    and not already a spawned child)."""
    return (getattr(args, "multihost", 0) or 0) > 1 \
        and getattr(args, "_mh_proc_id", None) is None


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def spawn_multihost(module: str, argv: Sequence[str], nprocs: int,
                    *, timeout: Optional[float] = None) -> int:
    """Re-launch ``python -m module argv`` as ``nprocs`` coordinated
    child processes and wait.  Child 0 streams to the parent's
    stdout/stderr (it owns all output writes); the others keep stderr
    for crash visibility but drop stdout.  Returns the max exit code."""
    coord = f"127.0.0.1:{free_port()}"
    procs: List[subprocess.Popen] = []
    for pid in range(nprocs):
        cmd = [sys.executable, "-m", module, *argv,
               "--_mh-coord", coord, "--_mh-procs", str(nprocs),
               "--_mh-proc-id", str(pid)]
        procs.append(subprocess.Popen(
            cmd, stdout=None if pid == 0 else subprocess.DEVNULL))
    codes = []
    try:
        for p in procs:
            codes.append(p.wait(timeout=timeout))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return max(codes) if codes else 0
