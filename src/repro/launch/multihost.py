"""Multi-process launch plumbing (jax-free: argparse + subprocess only).

``--multihost P`` runs a launcher as ``P`` cooperating jax processes —
on CPU this *emulates* a multi-host fleet by spawning ``P`` copies of
the same command wired to one local coordinator, each owning
``K / P`` of the ``clients`` mesh devices; on a real multi-host slice
the same flags describe the actual coordinator/process topology.

The spawn protocol is self-re-execution: the parent parses
``--multihost P``, picks a free coordinator port, and re-launches its
own ``python -m <module> <argv>`` ``P`` times with the hidden
``--_mh-coord/--_mh-procs/--_mh-proc-id`` flags appended; a child sees
``--_mh-proc-id`` and initializes ``jax.distributed`` instead of
re-spawning.  Output-writing call sites gate on ``jax.process_index()
== 0``.  This module stays importable before jax so launchers can parse
flags without initializing any backend.
"""
from __future__ import annotations

import socket
import subprocess
import sys
import time
from typing import Callable, List, Optional, Sequence, Tuple


def retry_with_backoff(fn: Callable, *, attempts: int = 3,
                       base_delay_s: float = 1.0,
                       desc: str = "operation"):
    """Call ``fn()`` with bounded retries and exponential backoff
    (1x, 2x, 4x ... ``base_delay_s``).  The final failure re-raises the
    last error wrapped with ``desc`` and the attempt count, so a
    flaky-but-fatal init (a peer that never comes up) reports what was
    being retried instead of a bare timeout."""
    last: Optional[BaseException] = None
    for attempt in range(attempts):
        try:
            return fn()
        except Exception as e:          # noqa: BLE001 — re-raised below
            last = e
            if attempt + 1 < attempts:
                delay = base_delay_s * (2 ** attempt)
                print(f"[multihost] {desc} failed "
                      f"(attempt {attempt + 1}/{attempts}): {e}; "
                      f"retrying in {delay:.0f}s", file=sys.stderr,
                      flush=True)
                time.sleep(delay)
    raise RuntimeError(
        f"{desc} failed after {attempts} attempts: {last}") from last


def add_multihost_arguments(ap) -> None:
    """Install ``--multihost`` plus the hidden child-process flags."""
    ap.add_argument("--multihost", type=int, default=0, metavar="P",
                    help="run as P cooperating jax processes (CPU: "
                         "emulated via spawned local processes); the "
                         "mesh's clients=K axis spans all of them "
                         "(K %% P == 0)")
    ap.add_argument("--_mh-coord", default=None, help=_SUPPRESS())
    ap.add_argument("--_mh-procs", type=int, default=None,
                    help=_SUPPRESS())
    ap.add_argument("--_mh-proc-id", type=int, default=None,
                    help=_SUPPRESS())


def _SUPPRESS() -> str:
    import argparse
    return argparse.SUPPRESS


def multihost_from_args(args) -> Optional[Tuple[str, int, int]]:
    """The child-process distributed-init triple ``(coordinator,
    num_processes, process_id)``, or None outside a spawned child."""
    pid = getattr(args, "_mh_proc_id", None)
    if pid is None:
        return None
    return (args._mh_coord, int(args._mh_procs), int(pid))


def should_spawn(args) -> bool:
    """True in the parent process of a ``--multihost P`` launch (P > 1
    and not already a spawned child)."""
    return (getattr(args, "multihost", 0) or 0) > 1 \
        and getattr(args, "_mh_proc_id", None) is None


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _reap(procs: Sequence[subprocess.Popen],
          grace_s: float = 5.0) -> None:
    """Terminate (then kill) every still-running child."""
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + grace_s
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()


def spawn_multihost(module: str, argv: Sequence[str], nprocs: int,
                    *, timeout: Optional[float] = None,
                    poll_s: float = 0.2) -> int:
    """Re-launch ``python -m module argv`` as ``nprocs`` coordinated
    child processes and wait.  Child 0 streams to the parent's
    stdout/stderr (it owns all output writes); the others keep stderr
    for crash visibility but drop stdout.  Returns the max exit code.

    Failure containment (ISSUE 10): the parent *polls* the whole fleet
    instead of joining rank by rank — when any peer dies with a nonzero
    status the survivors are reaped immediately (a dead rank would
    otherwise leave the rest blocked in a collective forever) and the
    error names the dead rank.  ``timeout`` bounds the whole launch the
    same way (exit code 124, like timeout(1))."""
    coord = f"127.0.0.1:{free_port()}"
    procs: List[subprocess.Popen] = []
    for pid in range(nprocs):
        cmd = [sys.executable, "-m", module, *argv,
               "--_mh-coord", coord, "--_mh-procs", str(nprocs),
               "--_mh-proc-id", str(pid)]
        procs.append(subprocess.Popen(
            cmd, stdout=None if pid == 0 else subprocess.DEVNULL))
    deadline = (time.monotonic() + timeout) if timeout else None

    def norm(c: int) -> int:
        # shell convention: death by signal S reports 128 + S, so a
        # SIGKILLed rank can never masquerade as success through max()
        return c if c >= 0 else 128 - c

    try:
        while True:
            codes = [p.poll() for p in procs]
            if all(c is not None for c in codes):
                return max(norm(c) for c in codes)
            dead = [(rank, c) for rank, c in enumerate(codes)
                    if c is not None and c != 0]
            if dead:
                rank, code = dead[0]
                what = (f"signal {-code}" if code < 0
                        else f"exit code {code}")
                print(f"[multihost] rank {rank}/{nprocs} died with "
                      f"{what}; reaping the surviving processes",
                      file=sys.stderr, flush=True)
                _reap(procs)
                # report the rank(s) that died on their own — the
                # survivors we just SIGTERMed would otherwise mask the
                # root cause with their 143s
                return max(norm(c) for _, c in dead)
            if deadline is not None and time.monotonic() > deadline:
                print(f"[multihost] launch exceeded {timeout:.0f}s; "
                      f"reaping all {nprocs} processes",
                      file=sys.stderr, flush=True)
                _reap(procs)
                return 124
            time.sleep(poll_s)
    finally:
        _reap(procs)
