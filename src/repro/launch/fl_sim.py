"""The paper's experiment driver: federated simulation over the IoV model.

Usage:
  PYTHONPATH=src python -m repro.launch.fl_sim --scheme dcs --rounds 10
  PYTHONPATH=src python -m repro.launch.fl_sim --scheme all --fast
  PYTHONPATH=src python -m repro.launch.fl_sim --mesh clients=8 --rounds 5
  PYTHONPATH=src python -m repro.launch.fl_sim --server event \\
      --churn-rate 0.3 --staleness weighted --staleness-lambda 1.0

Execution knobs (engine / fused probe / round overlap / mesh / the
event-driven server's churn, staleness and cadence axis) live on the
shared ``RunConfig`` (``fl/runconfig.py``) — the same flags drive
``launch/sweep.py``, and library callers pass the identical object to
``FLSimulation(cfg, run=...)``.

``--mesh clients=K`` partitions the in-round client axis over K devices:
the selection prefix runs shard_map'd (``selection_prefix_sharded``) and
the grouped trainer splits every cohort across the mesh with a psum'd
FedAvg.  On CPU the K devices are emulated host devices — the launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` before the
jax backend initializes (heavy imports are deferred into ``main`` for
exactly this reason); if the backend is already live, it raises with the
relaunch recipe instead of quietly running single-device.
"""
from __future__ import annotations

import argparse
import json
import time

SCHEMES = ("dcs", "ccs-fuzzy", "random")


def fast_config(scheme: str, **kw):
    """CPU-budget profile: same structure, smaller local datasets."""
    from repro.fl.partition import PartitionConfig
    from repro.fl.rounds import FLSimConfig
    part = PartitionConfig(big_quantity=kw.pop("big_quantity", 300),
                           small_quantity=45,
                           classes_per_client=kw.pop("classes_per_client", 9))
    return FLSimConfig(scheme=scheme, partition=part,
                       samples_per_class=kw.pop("samples_per_class", 600),
                       local_epochs=kw.pop("local_epochs", 1),
                       n_rounds=kw.pop("n_rounds", 10), **kw)


def paper_config(scheme: str, **kw):
    """Table 3 profile (expensive on CPU)."""
    from repro.fl.rounds import FLSimConfig
    return FLSimConfig(scheme=scheme, local_epochs=30, n_rounds=50,
                       deadline_s=20.0, **kw)


def main(argv=None) -> int:
    # argparse only below — jax must not initialize before the mesh
    # context can force emulated host devices
    from repro.fl.runconfig import add_run_arguments
    from repro.launch.cache import add_cache_arguments, resolve_cache_dir
    from repro.launch.multihost import (add_multihost_arguments,
                                        multihost_from_args, should_spawn,
                                        spawn_multihost)

    ap = argparse.ArgumentParser()
    ap.add_argument("--scheme", choices=SCHEMES + ("all",), default="dcs")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--paper-profile", action="store_true")
    ap.add_argument("--classes-per-client", type=int, default=9)
    ap.add_argument("--distribution", choices=("uniform", "extreme"),
                    default="uniform")
    add_run_arguments(ap)        # mesh / fused probe / overlap / server /
    #                              churn / staleness / cadence (RunConfig)
    add_multihost_arguments(ap)  # --multihost P + hidden child flags
    add_cache_arguments(ap)      # --jit-cache-dir
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if should_spawn(args):
        # parent of a --multihost P launch: re-exec ourselves P times
        # with the coordinator flags appended and wait
        return spawn_multihost("repro.launch.fl_sim",
                               list(argv) if argv is not None
                               else __import__("sys").argv[1:],
                               args.multihost)

    # --mesh may force emulated host devices, which only works before the
    # jax backend initializes — so the mesh context comes first and the
    # simulator imports stay inside main
    from repro.launch.mesh import client_mesh_context
    with client_mesh_context(args.mesh,
                             multihost=multihost_from_args(args)) as mesh:
        import jax
        from repro.fl.mobility import MobilityConfig
        from repro.fl.rounds import FLSimulation
        from repro.fl.runconfig import RunConfig
        from repro.launch.cache import enable_jit_cache
        is_lead = jax.process_index() == 0
        enable_jit_cache(resolve_cache_dir(args.jit_cache_dir,
                                           args.out or "fl_sim.json"))
        if mesh is not None and is_lead:
            print(f"[fl_sim] client mesh: {dict(mesh.shape)} over "
                  f"{mesh.devices.size} devices"
                  + (f" / {jax.process_count()} processes"
                     if jax.process_count() > 1 else ""), flush=True)
        run = RunConfig.from_args(args)
        if run.server == "event" and is_lead:
            print(f"[fl_sim] event-driven server: churn={run.churn_rate} "
                  f"staleness={run.staleness} lam={run.staleness_lambda} "
                  f"cadence={run.agg_cadence_s or 'round period'}",
                  flush=True)

        schemes = SCHEMES if args.scheme == "all" else (args.scheme,)
        results = {}
        for scheme in schemes:
            mk = paper_config if args.paper_profile else fast_config
            cfg = mk(scheme, n_rounds=args.rounds,
                     classes_per_client=args.classes_per_client,
                     seed=args.seed) \
                if not args.paper_profile else mk(scheme, seed=args.seed)
            cfg.mobility = MobilityConfig(distribution=args.distribution,
                                          seed=args.seed)
            srun = run
            if run.checkpoint_dir:
                # one snapshot directory per scheme, so --scheme all
                # runs never overwrite each other's round state
                import dataclasses
                import os
                srun = dataclasses.replace(
                    run, checkpoint_dir=os.path.join(run.checkpoint_dir,
                                                     scheme))
            sim = FLSimulation(cfg, run=srun)
            t0 = time.time()
            hist = sim.run(args.rounds)
            dt = time.time() - t0
            accs = [h["accuracy"] for h in hist]
            nsel = sum(h["n_selected"] for h in hist) / len(hist)
            if is_lead:
                print(f"[fl_sim] {scheme}: final acc {accs[-1]:.3f} "
                      f"(best {max(accs):.3f}), avg selected {nsel:.2f}, "
                      f"{dt:.0f}s", flush=True)
            results[scheme] = hist
    if args.out and is_lead:     # one writer in a multi-process launch
        from repro.ioutil import write_atomic_json
        write_atomic_json(args.out, results, indent=1)
        print(f"[fl_sim] wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
