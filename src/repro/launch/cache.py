"""Persistent jit compilation cache wiring (ISSUE 9 satellite).

Sweep workers and ``fl_sim`` re-trace the same round executables for
every (seed, scheme, partition) cell; on CPU the XLA pipeline dominates
short runs.  ``enable_jit_cache`` points jax's persistent compilation
cache at a directory so repeat launches (and sibling sweep workers) hit
disk instead of recompiling.  CPU compiles are fast and small, so the
default persistence thresholds (min compile seconds / min entry bytes)
would skip everything — both are forced to "always persist".
"""
from __future__ import annotations

import logging
import os
from typing import Optional

logger = logging.getLogger(__name__)


def resolve_cache_dir(arg: Optional[str], output_path: str) -> Optional[str]:
    """The effective cache directory for ``--jit-cache-dir``.

    ``None`` (flag absent) defaults to ``.jit-cache`` next to the run's
    output file; an explicit empty string or "none" disables caching."""
    if arg is not None:
        if arg.strip().lower() in ("", "none", "off"):
            return None
        return arg
    base = os.path.dirname(os.path.abspath(output_path))
    return os.path.join(base, ".jit-cache")


def enable_jit_cache(path: Optional[str]) -> Optional[str]:
    """Activate jax's persistent compilation cache at ``path``.

    Must run after jax import but before the first jit compilation.
    Returns the path (or None when disabled) for logging."""
    if not path:
        return None
    import jax
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # CPU executables compile in <1s and serialize small; the default
    # thresholds would persist nothing
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    logger.info("persistent jit cache at %s", path)
    return path


def add_cache_arguments(ap) -> None:
    ap.add_argument("--jit-cache-dir", default=None, metavar="DIR",
                    help="persistent jit compilation cache directory "
                         "(default: .jit-cache beside the output file; "
                         "'none' disables)")
