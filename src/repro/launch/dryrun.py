import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

No real allocation: inputs are ShapeDtypeStructs; the 512 placeholder CPU
devices exist only so jax.make_mesh can build the production meshes.

Per combo this script records to JSONL:
  - memory_analysis (argument/output/temp/peak bytes per device),
  - cost_analysis flops / bytes accessed (per device, post-SPMD),
  - collective bytes by op kind parsed from the compiled HLO,
  - the three roofline terms and the dominant one (v5e constants),
  - MODEL_FLOPS (6·N·D train / 2·N_active·D decode) and the useful-compute
    ratio vs compiled HLO flops.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] --out results.jsonl
"""
import argparse
import functools
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SHAPES, get_arch, get_shape)
from repro.launch import hlo_cost
from repro.launch import mesh as meshlib
from repro.models import registry as R
from repro.models import transformer as tfm
from repro.serve.engine import make_serve_step
from repro.sharding import (DEFAULT_RULES, batch_shardings, cache_shardings,
                            logical_sharding, param_shardings, replicated)
from repro.train.optim import OptConfig, adamw_init
from repro.train.step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# collective-bytes parser (post-SPMD HLO text)
# --------------------------------------------------------------------------

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op, by kind.

    Shapes in the post-SPMD module are per-device; '-start' async forms are
    counted, their '-done' halves skipped.
    """
    by_kind = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        lhs, _, rhs = line.partition("=")
        m = re.search(r"\b([a-z\-]+)(?:-start)?\(", rhs.strip())
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLL_KINDS if op == k or op == k + "-start"),
                    None)
        if kind is None:
            continue
        # output shape(s) are on the RHS head: "... = (f32[..],..) op(...)"
        head = rhs.strip().split(" ", 1)[0] if rhs.strip().startswith("(") \
            else rhs.strip().split(" ", 1)[0]
        by_kind[kind] += _shape_bytes(head)
        counts[kind] += 1
    total = sum(by_kind.values())
    # effective traffic: all-reduce moves ~2x its payload (RS+AG)
    weighted = total + by_kind["all-reduce"]
    return {"bytes_by_kind": by_kind, "counts": counts,
            "total_bytes": total, "weighted_bytes": weighted}


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------

def roofline(flops_per_dev: float, hbm_bytes_per_dev: float,
             coll_bytes_per_dev: float) -> Dict[str, Any]:
    t_c = flops_per_dev / meshlib.PEAK_FLOPS_BF16
    t_m = hbm_bytes_per_dev / meshlib.HBM_BW
    t_n = coll_bytes_per_dev / meshlib.ICI_BW_PER_LINK
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom, "bound_s": max(t_c, t_m, t_n)}


def model_flops(cfg, shape) -> float:
    n_active = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch          # decode: 1 token/seq


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------

def _params_shape(cfg):
    return jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                donate: bool = True,
                rules: Optional[Dict] = None) -> Tuple[Any, Any]:
    """Returns (lowered, meta) for one (arch x shape x mesh)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    rules = dict(DEFAULT_RULES if rules is None else rules)

    p_shape = _params_shape(cfg)
    p_sh = param_shardings(p_shape, mesh, cfg)

    with mesh, logical_sharding(mesh, rules):
        if shape.kind == "train":
            opt = OptConfig()
            o_shape = jax.eval_shape(adamw_init, p_shape)
            o_sh = param_shardings(o_shape, mesh, cfg)
            b_shape = R.train_batch_spec(cfg, shape)
            b_sh = batch_shardings(b_shape, mesh)
            step = make_train_step(cfg, shape, opt)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(p_shape, o_shape, b_shape)
        elif shape.kind == "prefill":
            b_shape = R.prefill_batch_spec(cfg, shape)
            b_sh = batch_shardings(b_shape, mesh)
            cache_shape = jax.eval_shape(
                functools.partial(tfm.prefill, cfg), p_shape, b_shape)[1]
            c_sh = cache_shardings(cache_shape, mesh, cfg)
            fn = functools.partial(tfm.prefill, cfg)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(p_shape, b_shape)
        else:                                           # decode
            tok_shape, cache_shape = R.decode_inputs_spec(cfg, shape)
            c_sh = cache_shardings(cache_shape, mesh, cfg)
            t_sh = batch_shardings(tok_shape, mesh)
            step = make_serve_step(cfg, shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh["tokens"]),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(p_shape, cache_shape,
                                   tok_shape["tokens"])
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind}
    return lowered, meta


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              rules: Optional[Dict] = None,
              verbose: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    lowered, meta = lower_combo(arch, shape_name, multi_pod=multi_pod,
                                rules=rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)              # trip-count-aware, per device

    flops = float(cost.flops)
    bytes_acc = float(cost.hbm_bytes)
    coll = {"total_bytes": cost.collective_bytes,
            "weighted_bytes": cost.collective_weighted,
            "bytes_by_kind": cost.by_kind, "counts": cost.counts}
    rl = roofline(flops, bytes_acc, coll["weighted_bytes"])
    mf = model_flops(cfg, shape)
    n_chips = 512 if multi_pod else 256
    useful = mf / max(flops * n_chips, 1.0)

    peak_bytes = (getattr(mem, "temp_size_in_bytes", 0)
                  + getattr(mem, "argument_size_in_bytes", 0)
                  + getattr(mem, "output_size_in_bytes", 0)
                  - getattr(mem, "alias_size_in_bytes", 0))
    row = dict(meta)
    row.update({
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_dev": flops,
        "hbm_bytes_per_dev": bytes_acc,
        "collective_bytes_per_dev": coll["total_bytes"],
        "collective_weighted_bytes": coll["weighted_bytes"],
        "collective_by_kind": coll["bytes_by_kind"],
        "collective_counts": coll["counts"],
        "xla_cost_flops_once": float(xla_cost.get("flops", 0.0)),
        "roofline": rl,
        "model_flops_global": mf,
        "useful_compute_ratio": useful,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": peak_bytes,
            "fits_16g": bool(peak_bytes < meshlib.HBM_BYTES_PER_CHIP),
        },
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {row['mesh']}: "
              f"compile {t_compile:.0f}s, "
              f"flops/dev {flops:.3g}, hbm/dev {bytes_acc:.3g}B, "
              f"coll/dev {coll['total_bytes']:.3g}B, "
              f"dominant={rl['dominant']}, peak {peak_bytes/2**30:.2f} GiB",
              flush=True)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    combos = []
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, mp))

    done = set()
    if args.out and args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    failures = 0
    for a, s, mp in combos:
        meshname = "2x16x16" if mp else "16x16"
        if (a, s, meshname) in done:
            print(f"[dryrun] skip {a} x {s} x {meshname} (done)", flush=True)
            continue
        try:
            row = run_combo(a, s, multi_pod=mp)
        except Exception as e:                      # noqa: BLE001
            failures += 1
            row = {"arch": a, "shape": s, "mesh": meshname, "ok": False,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[dryrun] FAIL {a} x {s} x {meshname}: {row['error']}",
                  flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
