"""Static cost analysis of post-SPMD HLO text, with loop trip counts.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE,
which undercounts scanned-layer models by O(layers x grad_accum).  This
analyzer parses the compiled module text and walks the call graph:

- ``while`` ops multiply their body cost by the ``known_trip_count``
  backend_config (1 if absent);
- ``fusion`` ops contribute operand+output bytes at the fusion boundary
  (the fused interior is not HBM traffic) and the MXU flops of any dots
  inside the fused computation;
- ``dot`` flops = 2 * numel(output) * contraction_size;
- collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute, incl. async -start forms) accumulate output bytes,
  weighted x2 for all-reduce (RS+AG traffic);
- top-level non-fused element-wise ops contribute operand+output bytes.

All shapes in a post-SPMD module are per-device, so every total this
module reports is per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, shape in _parse_shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel_of_first(text: str) -> int:
    shapes = _parse_shapes(text)
    if not shapes:
        return 0
    n = 1
    for d in shapes[0][1]:
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0            # raw output bytes
    collective_weighted: float = 0.0         # all-reduce x2
    by_kind: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_weighted += other.collective_weighted * mult
        for k, v in other.by_kind.items():
            self.by_kind[k] = self.by_kind.get(k, 0.0) + v * mult
        for k, v in other.counts.items():
            self.counts[k] = self.counts.get(k, 0.0) + v * mult


@dataclass
class Op:
    name: str
    rhs: str              # full right-hand side text
    out_text: str         # output type text (before opcode)
    opcode: str
    operands: List[str]


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}   # (comp, op) -> type
        self._parse(text)
        self._cache: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line.strip())
            if hdr and line.strip().endswith("{"):
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # out type = prefix of rhs up to the opcode token
            om = re.match(r"((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)"
                          r"(?:\s*))\s*([\w\-]+)\(", rhs)
            if not om:
                continue
            out_text, opcode = om.group(1), om.group(2)
            operands = re.findall(r"%([\w.\-]+)", rhs[om.end():])
            self.computations[cur].append(
                Op(name, rhs, out_text, opcode, operands))
            self.shapes[(cur, name)] = out_text

    # ------------------------------------------------------------------
    def _operand_bytes(self, comp: str, op: Op) -> int:
        total = 0
        seen = 0
        for o in op.operands:
            t = self.shapes.get((comp, o))
            if t is None:
                continue
            total += _bytes_of(t)
            seen += 1
            if seen >= 8:          # cap: variadic fusions w/ huge arg lists
                break
        return total

    def _io_bytes(self, comp: str, op: Op) -> int:
        """HBM traffic of one op: operands + output, EXCEPT when an operand
        aliases the output (in-place dynamic-update-slice patterns on
        loop-carried buffers): then only the non-aliased operands move,
        twice (read slice inputs + write same amount)."""
        out_b = _bytes_of(op.out_text)
        out_shape = _parse_shapes(op.out_text)
        aliased = None
        op_bytes = []
        for o in op.operands[:8]:
            t = self.shapes.get((comp, o))
            if t is None:
                continue
            b = _bytes_of(t)
            if (aliased is None and out_shape
                    and _parse_shapes(t) == out_shape
                    and ("dynamic-update-slice" in op.rhs
                         or "dynamic-update-slice" in op.name)):
                aliased = b
                continue
            op_bytes.append(b)
        if aliased is not None:
            return 2 * sum(op_bytes)
        return out_b + sum(op_bytes)

    def _dot_flops(self, comp: str, op: Op) -> float:
        out_numel = _numel_of_first(op.out_text)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
        k = 1
        if m and op.operands:
            lhs_t = self.shapes.get((comp, op.operands[0]))
            if lhs_t:
                shapes = _parse_shapes(lhs_t)
                if shapes:
                    dims = shapes[0][1]
                    for d in m.group(1).split(","):
                        if d and int(d) < len(dims):
                            k *= dims[int(d)]
        return 2.0 * out_numel * k

    def _conv_flops(self, comp: str, op: Op) -> float:
        out_numel = _numel_of_first(op.out_text)
        if len(op.operands) >= 2:
            kt = self.shapes.get((comp, op.operands[1]))
            if kt:
                shapes = _parse_shapes(kt)
                if shapes:
                    n = 1
                    for d in shapes[0][1]:
                        n *= d
                    # kernel numel / out_channels ~ per-output MACs
                    out_c = shapes[0][1][-1] if shapes[0][1] else 1
                    return 2.0 * out_numel * max(n // max(out_c, 1), 1)
        return 2.0 * out_numel

    # ------------------------------------------------------------------
    def _comp_flops_only(self, comp: str) -> float:
        """MXU flops inside a (fused) computation."""
        total = 0.0
        for op in self.computations.get(comp, []):
            if op.opcode == "dot":
                total += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                total += self._conv_flops(comp, op)
        return total

    def cost_of(self, comp: str) -> Cost:
        if comp in self._cache:
            return self._cache[comp]
        c = Cost()
        self._cache[comp] = c                    # guards recursion
        for op in self.computations.get(comp, []):
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            if oc in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "iota"):
                continue
            if base in COLLECTIVES:
                b = _bytes_of(op.out_text)
                w = 2.0 * b if base == "all-reduce" else float(b)
                c.collective_bytes += b
                c.collective_weighted += w
                c.by_kind[base] = c.by_kind.get(base, 0.0) + b
                c.counts[base] = c.counts.get(base, 0.0) + 1
                c.hbm_bytes += b
                continue
            if oc.endswith("-done"):
                continue
            if oc == "while":
                m = _TRIP_RE.search(op.rhs)
                trip = int(m.group(1)) if m else 1
                bm = re.search(r"body=%?([\w.\-]+)", op.rhs)
                if bm and bm.group(1) in self.computations:
                    c.add(self.cost_of(bm.group(1)), mult=trip)
                continue
            if oc in ("call", "custom-call", "async-start"):
                tm = re.search(r"(?:to|called_computations?)=\{?%?([\w.\-]+)",
                               op.rhs)
                if tm and tm.group(1) in self.computations:
                    c.add(self.cost_of(tm.group(1)))
                else:
                    c.hbm_bytes += _bytes_of(op.out_text) \
                        + self._operand_bytes(comp, op)
                continue
            if oc == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.rhs)
                sub = [self.cost_of(b) for b in branches
                       if b in self.computations]
                if sub:
                    best = max(sub, key=lambda s: s.flops + s.hbm_bytes)
                    c.add(best)
                continue
            if oc == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", op.rhs)
                if fm:
                    c.flops += self._comp_flops_only(fm.group(1))
                c.hbm_bytes += self._io_bytes(comp, op)
                continue
            if oc == "dot":
                c.flops += self._dot_flops(comp, op)
                c.hbm_bytes += self._io_bytes(comp, op)
                continue
            if oc == "convolution":
                c.flops += self._conv_flops(comp, op)
                c.hbm_bytes += self._io_bytes(comp, op)
                continue
            # generic op: moves its operands + output through HBM
            c.hbm_bytes += self._io_bytes(comp, op)
            c.flops += _numel_of_first(op.out_text)      # ~1 flop/elem
        return c

    def total(self) -> Cost:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).total()
