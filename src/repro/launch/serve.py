"""Serving driver: batched prefill + decode on any assigned architecture.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, scaled_down
from repro.models import transformer as tfm
from repro.serve.engine import generate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = scaled_down(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, cfg)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix"] = jax.random.normal(
            key, (args.batch, cfg.num_prefix_tokens, cfg.d_model))

    t0 = time.time()
    toks, info = generate(cfg, params, batch, args.max_new,
                          temperature=args.temperature, key=key)
    toks = jax.block_until_ready(toks)
    dt = time.time() - t0
    n_tok = args.batch * args.max_new
    print(f"[serve] {cfg.name}: generated {toks.shape} in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s)")
    print(f"[serve] first sequence: {toks[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
