"""Production mesh construction (TPU v5e pods; 256 chips/pod).

Defined as functions — importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count
=512 *before* any jax import to build these meshes on CPU.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 0, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants for the roofline (per chip / per link)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s
CHIPS_PER_POD = 256
HBM_BYTES_PER_CHIP = 16 * 1024**3
