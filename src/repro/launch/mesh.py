"""Production mesh construction (TPU v5e pods; 256 chips/pod) plus the
FL launchers' ``clients`` mesh.

Defined as functions — importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count
=512 *before* any jax import to build these meshes on CPU; the FL
launchers (``fl_sim``/``sweep`` with ``--mesh clients=K``) do the same
through ``ensure_host_device_count`` before their first jax operation.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.sharding.api import CLIENT_AXIS


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 0, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"cannot build a debug mesh: {n} devices not divisible by "
            f"model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_clients_mesh(n_shards: int = 0) -> Mesh:
    """1-D ``("clients",)`` mesh over the first ``n_shards`` local devices
    — the launcher's ``--mesh clients=K``.  ``0`` takes every device."""
    devices = jax.devices()
    n = n_shards or len(devices)
    if n < 1:
        raise ValueError(f"clients mesh needs >= 1 shard, got {n}")
    if n > len(devices):
        raise ValueError(
            f"clients mesh wants {n} devices but only {len(devices)} "
            f"exist; on CPU, relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    return Mesh(np.asarray(devices[:n]), (CLIENT_AXIS,))


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int, local_devices: int = 1) -> None:
    """Join a multi-process jax runtime (``--multihost`` children).

    Must run before the first jax operation: the host-device count flag
    and the CPU collectives backend are only read at backend init.  On
    CPU, cross-process collectives go through gloo; each process
    contributes ``local_devices`` emulated host devices, so the global
    device count is ``num_processes * local_devices``.

    Robustness (ISSUE 10): the barrier-at-init is where a dead or
    never-started peer used to hang a launch forever.  The init now runs
    under a hard timeout (``REPRO_DIST_TIMEOUT_S``, default 60s) with
    bounded retries + backoff (``REPRO_DIST_INIT_ATTEMPTS``, default 3),
    and the terminal error names this rank and the coordinator."""
    from repro.launch.multihost import retry_with_backoff
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{local_devices}".strip())
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    timeout_s = int(float(os.environ.get("REPRO_DIST_TIMEOUT_S", "60")))
    attempts = int(os.environ.get("REPRO_DIST_INIT_ATTEMPTS", "3"))

    def _init():
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=num_processes, process_id=process_id,
                initialization_timeout=timeout_s)
        except TypeError:
            # older jax without the kwarg: fall back to its default
            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=num_processes, process_id=process_id)

    retry_with_backoff(
        _init, attempts=attempts,
        desc=(f"jax.distributed init (rank {process_id}/{num_processes} "
              f"via {coordinator})"))


def make_multihost_clients_mesh(n_shards: int) -> Mesh:
    """1-D ``("clients",)`` mesh over the GLOBAL device list of an
    initialized multi-process runtime.  ``jax.devices()`` orders global
    devices by (process_index, local id), so shard ``d`` lives on
    process ``d // (K / P)`` — the per-host client-loading seam in
    ``fl/rounds.py`` relies on that contiguity."""
    devices = jax.devices()
    if n_shards != len(devices):
        raise ValueError(
            f"multihost clients mesh wants clients={n_shards} but the "
            f"distributed runtime exposes {len(devices)} global devices "
            f"({jax.process_count()} processes x "
            f"{len(jax.local_devices())} local)")
    return Mesh(np.asarray(devices), (CLIENT_AXIS,))


def parse_mesh_spec(spec: str) -> Dict[str, int]:
    """``"clients=8"`` (comma-separable) -> ``{"clients": 8}``."""
    out: Dict[str, int] = {}
    for part in spec.split(","):
        name, _, val = part.partition("=")
        name = name.strip()
        if not name or not val:
            raise ValueError(f"bad mesh axis {part!r} (want axis=N)")
        try:
            out[name] = int(val)
        except ValueError:
            raise ValueError(f"bad mesh extent {val!r} for axis {name!r}")
    return out


@contextlib.contextmanager
def client_mesh_context(spec: Optional[str],
                        multihost: Optional[Tuple[str, int, int]] = None):
    """``--mesh`` handling shared by the FL launchers: ``"clients=K"``
    builds the K-way clients mesh (forcing K emulated CPU host devices
    when the backend has not initialized yet) and activates it plus the
    logical sharding rules for every simulation constructed inside.
    ``None``/empty is a no-op single-device context.

    ``multihost=(coordinator, num_processes, process_id)`` — a spawned
    ``--multihost`` child — first joins the distributed runtime; the
    spec's ``clients=K`` is then the GLOBAL extent (``K %%
    num_processes == 0``, each process contributing ``K / P`` emulated
    devices) and the mesh spans every process."""
    if multihost is not None:
        coord, procs, pid = multihost
        if not spec:
            raise ValueError("--multihost needs --mesh clients=K (the "
                             "client axis is what spans the processes)")
        axes = parse_mesh_spec(spec)
        k = axes.get(CLIENT_AXIS, 1)
        if procs < 1 or k % procs != 0:
            raise ValueError(
                f"--mesh clients={k} must divide evenly over "
                f"--multihost {procs} processes")
        # chaos hook: kill one rank before it joins the barrier, so the
        # parent's peer-death reaping (spawn_multihost) is exercised
        from repro.launch import faults
        faults.fire("mh-child-start", rank=pid)
        init_distributed(coord, procs, pid, local_devices=k // procs)
        mesh = make_multihost_clients_mesh(k)
        from repro.sharding.api import DEFAULT_RULES, logical_sharding
        with mesh, logical_sharding(mesh, DEFAULT_RULES):
            yield mesh
        return
    if not spec:
        yield None
        return
    axes = parse_mesh_spec(spec)
    unknown = sorted(set(axes) - {CLIENT_AXIS})
    if unknown:
        raise ValueError(f"unknown mesh axes {unknown} (the FL launchers "
                         f"only partition {CLIENT_AXIS!r})")
    k = axes.get(CLIENT_AXIS, 1)
    if k > 1:
        ensure_host_device_count(k)
    mesh = make_clients_mesh(k)
    from repro.sharding.api import DEFAULT_RULES, logical_sharding
    with mesh, logical_sharding(mesh, DEFAULT_RULES):
        yield mesh


def ensure_host_device_count(n: int) -> None:
    """Best-effort CPU host-device emulation for ``--mesh clients=K``.

    Appends ``--xla_force_host_platform_device_count=N`` to XLA_FLAGS —
    effective only if the jax backend has not initialized yet, which is
    why the launchers call this before their first jax operation.  If the
    devices still do not materialize (backend already live, or a real
    accelerator platform), raises with the relaunch recipe instead of
    quietly running single-device."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())
    if len(jax.devices()) < n:
        raise RuntimeError(
            f"requested {n} devices but only {len(jax.devices())} "
            f"materialized (jax backend already initialized?); set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} in the "
            f"environment before launching")


# v5e hardware constants for the roofline (per chip / per link)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s
CHIPS_PER_POD = 256
HBM_BYTES_PER_CHIP = 16 * 1024**3
