"""End-to-end training driver.

CPU-scale driver: trains a reduced variant of any assigned arch on the
synthetic LM stream (examples use it for ~100M-class models).  On real
hardware the same code path drives the production mesh: pass
``--mesh prod`` under a pod slice and the full config lowers exactly as
the dry-run proved.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
      --steps 200 --batch 8 --seq 256 --reduced
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch, scaled_down
from repro.configs.base import ShapeConfig
from repro.data.lm import SyntheticLM
from repro.models import registry as R
from repro.models import transformer as tfm
from repro.train.optim import OptConfig, adamw_init
from repro.train.step import make_train_step
from repro.train.checkpoint import save_checkpoint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the family")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = scaled_down(cfg, layers=args.layers, d_model=args.d_model)
    shape = ShapeConfig("cli", args.seq, args.batch, "train",
                        grad_accum=args.grad_accum)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5),
                        schedule=cfg.lr_schedule)

    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, cfg)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params "
          f"({'reduced' if args.reduced else 'full'})", flush=True)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, shape, opt_cfg),
                      donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab_size, seed=args.seed)
    it = data.batches(args.batch, args.seq, cfg)

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if jnp.ndim(v) == 0}
            print(f"[train] step {step:5d} loss {m['loss']:.4f} "
                  f"ce {m['ce']:.4f} lr {m['lr']:.2e} "
                  f"gnorm {m['grad_norm']:.2f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt_state, step=args.steps,
                        extra={"arch": cfg.name})
        print(f"[train] checkpoint -> {args.ckpt}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
