from repro.train.optim import (OptConfig, adamw_init, adamw_update,
                               schedule_lr, sgd_update)
from repro.train.step import make_eval_step, make_train_step
from repro.train.checkpoint import load_checkpoint, save_checkpoint

__all__ = [
    "OptConfig", "adamw_init", "adamw_update", "schedule_lr", "sgd_update",
    "make_eval_step", "make_train_step", "load_checkpoint", "save_checkpoint",
]
