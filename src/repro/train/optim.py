"""Optimizers and LR schedules (pure JAX — no optax).

AdamW with decoupled weight decay; schedules: linear-warmup cosine and
MiniCPM's WSD (warmup-stable-decay, arXiv:2404.06395 §4).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd | constant
    wsd_decay_frac: float = 0.1       # last 10% of steps decay (WSD)
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.wsd_decay_frac)
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(cfg.total_steps - decay_start, 1.0),
                        0.0, 1.0)
        # exponential-style anneal to min_lr_frac
        stable = cfg.lr
        decayed = cfg.lr * jnp.power(cfg.min_lr_frac, frac)
        return warm * jnp.where(step < decay_start, stable, decayed)
    # cosine
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1.0),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    return warm * (cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos))


def adamw_init(params: Params) -> Dict[str, Any]:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def _global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads: Params, state: Dict[str, Any],
                 params: Params) -> Tuple[Params, Dict[str, Any], Dict]:
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.betas

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    grads = jax.tree.map(lambda g: g * scale, grads)

    m = jax.tree.map(lambda mo, g: b1 * mo + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vo, g: b2 * vo + (1 - b2) * jnp.square(g),
                     state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mo, vo):
        mhat = mo / bc1
        vhat = vo / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices only
            u = u + cfg.weight_decay * p
        return p - lr * u

    params = jax.tree.map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# --------------------------------------------------------------------------
# plain SGD — the paper's local update rule (Eq. 1)
# --------------------------------------------------------------------------

def sgd_update(params: Params, grads: Params, lr: float) -> Params:
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
