"""Training step factory: grad-accumulation microbatching + AdamW.

``make_train_step(cfg, shape, opt)`` returns a jit-able
``train_step(params, opt_state, batch)`` that scans ``shape.grad_accum``
microbatches (activation memory / grad_accum), accumulates fp32 grads,
then applies one optimizer update.  This is the function the multi-pod
dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.scanopt import SCAN_UNROLL
from repro.train.optim import OptConfig, adamw_update


def _split_micro(batch: Dict[str, jax.Array], ga: int) -> Dict[str, jax.Array]:
    """(GB, ...) -> (ga, GB/ga, ...) for every leaf."""
    def r(x):
        return x.reshape((ga, x.shape[0] // ga) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ArchConfig, shape: ShapeConfig,
                    opt: OptConfig) -> Callable:
    ga = max(1, shape.grad_accum)
    loss_fn = functools.partial(tfm.train_loss, cfg)
    # microbatch loop: chunk-unrolled per the shared XLA:CPU slow-path
    # policy (repro/scanopt.py).  Unlike fl/client.py's CNN steps, the
    # body here is a full transformer grad, so the cap is SCAN_UNROLL
    # even for small ga — never the full-unroll regime, which would
    # multiply transformer lowering time for a body that is already
    # compute-bound.  Same microbatches, same order.
    unroll = min(ga, SCAN_UNROLL)

    def train_step(params, opt_state, batch):
        micro = _split_micro(batch, ga)

        def micro_step(carry, mb):
            g_acc, l_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 g_acc, grads)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), ms = jax.lax.scan(
            micro_step, (g0, jnp.float32(0.0)), micro, unroll=unroll)
        grads = jax.tree.map(lambda g: g / ga, grads)
        params, opt_state, opt_metrics = adamw_update(
            opt, grads, opt_state, params)
        metrics = {k: v.mean() for k, v in ms.items()}
        metrics.update(opt_metrics)
        metrics["loss"] = loss_sum / ga
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    loss_fn = functools.partial(tfm.train_loss, cfg)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
