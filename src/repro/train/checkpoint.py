"""Checkpointing (ISSUE 10): self-describing, checksummed, atomic round
snapshots plus the legacy params/opt-state API.

Two layers:

- ``save_state`` / ``load_state`` — the **v2 state format**.  An
  arbitrary pytree of dicts / lists / tuples / ``None`` / array leaves /
  Python scalars is flattened to raw little-endian byte buffers inside
  one ``arrays.npz`` (every entry stored as ``uint8`` bytes, so exotic
  dtypes like ``bfloat16`` round-trip **bit-identically** — npz's native
  dtype descriptors cannot represent them) and a JSON ``manifest.json``
  carrying the structure skeleton (container kinds, dtypes, shapes,
  Python-scalar tags), a sha256 checksum of the array payload, and an
  arbitrary JSON ``extra``.  ``load_state`` needs no template: the
  skeleton rebuilds the exact structure, leaves bit-for-bit.

  Write order is the durability contract: ``arrays.npz`` is written
  atomically first (``repro.ioutil.write_atomic``), the manifest —
  which carries the checksum — atomically last.  The manifest is the
  commit point: a kill between the two leaves an array file without a
  manifest, which readers treat as "no checkpoint here", and any
  post-commit corruption of the array payload fails the checksum.  A
  torn or truncated checkpoint is therefore **detected, never silently
  loaded** (``CheckpointCorruptError``).

- ``save_checkpoint`` / ``load_checkpoint`` — the legacy (params,
  opt_state, step) API, now layered on the v2 format.  ``load_checkpoint``
  restores into the caller's template and validates **everything** the
  old format let slide: the stored params treedef must match the
  template's, and every leaf's dtype and shape must match exactly — a
  mismatch raises with the offending '/'-joined key path instead of
  silently casting.

``RoundCheckpointer`` manages a directory of per-round snapshots for
the FL drivers (``fl/rounds.py`` / ``fl/async_server.py`` / the sweep's
seed groups): ``save_round`` writes ``round_NNNNNN/``, prunes old
rounds beyond ``keep``, and ``latest_good`` walks rounds newest-first,
**skipping corrupt or half-written snapshots with a warning**
(``CheckpointCorruptWarning``) until a verified one loads — the
degrade-gracefully contract the fault-injection suite
(tests/test_faults.py) pins.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ioutil import sha256_file, write_atomic, write_atomic_json

FORMAT_VERSION = 2

_ARRAYS = "arrays.npz"
_MANIFEST = "manifest.json"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint exists but fails validation (missing pieces, bad
    checksum, undecodable skeleton) — refuse to load it."""


class CheckpointCorruptWarning(RuntimeWarning):
    """A corrupt snapshot was detected and skipped (``latest_good``)."""


# -- v2 self-describing state format -----------------------------------

def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype by name, including the ml_dtypes extension types
    jax registers (bfloat16 & friends)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode(node: Any, flat: Dict[str, np.ndarray],
            counter: List[int]) -> Dict[str, Any]:
    """Recursively encode a pytree node into a JSON skeleton, collecting
    array payloads (as raw byte buffers) into ``flat``."""
    if node is None:
        return {"kind": "none"}
    if isinstance(node, dict):
        return {"kind": "dict",
                "items": {str(k): _encode(v, flat, counter)
                          for k, v in node.items()}}
    if isinstance(node, tuple):
        return {"kind": "tuple",
                "items": [_encode(v, flat, counter) for v in node]}
    if isinstance(node, list):
        return {"kind": "list",
                "items": [_encode(v, flat, counter) for v in node]}
    # leaf: a jax/numpy array or a Python/numpy scalar.  Stored as raw
    # bytes: npz then only ever carries uint8 buffers, so any dtype —
    # including bfloat16 — survives bit-for-bit.
    py = None
    if isinstance(node, bool):
        py = "bool"
    elif isinstance(node, int):
        py = "int"
    elif isinstance(node, float):
        py = "float"
    arr = np.asarray(node)
    if arr.dtype == object:
        raise TypeError(f"cannot checkpoint object-dtype leaf: {node!r}")
    key = f"a{counter[0]:06d}"
    counter[0] += 1
    flat[key] = np.frombuffer(
        np.ascontiguousarray(arr).tobytes(), dtype=np.uint8)
    return {"kind": "leaf", "key": key, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "py": py}


def _decode(skel: Dict[str, Any], flat: Dict[str, np.ndarray]) -> Any:
    kind = skel["kind"]
    if kind == "none":
        return None
    if kind == "dict":
        return {k: _decode(v, flat) for k, v in skel["items"].items()}
    if kind == "tuple":
        return tuple(_decode(v, flat) for v in skel["items"])
    if kind == "list":
        return [_decode(v, flat) for v in skel["items"]]
    if kind != "leaf":
        raise CheckpointCorruptError(f"unknown skeleton kind {kind!r}")
    raw = flat[skel["key"]]
    arr = np.frombuffer(raw.tobytes(), dtype=_np_dtype(skel["dtype"]))
    arr = arr.reshape(skel["shape"])
    py = skel.get("py")
    if py == "bool":
        return bool(arr.reshape(()))
    if py == "int":
        return int(arr.reshape(()))
    if py == "float":
        return float(arr.reshape(()))
    return arr


def save_state(path: str, state: Any,
               extra: Optional[Dict[str, Any]] = None) -> None:
    """Atomically snapshot ``state`` (an arbitrary pytree of containers,
    arrays and Python scalars) under directory ``path``.

    ``extra`` is an arbitrary JSON-serializable sidecar (round indices,
    metric rows, config echoes) stored in the manifest and returned
    verbatim by ``load_state``.  The manifest write is the commit
    point — see the module docstring for the durability contract."""
    os.makedirs(path, exist_ok=True)
    flat: Dict[str, np.ndarray] = {}
    skeleton = _encode(jax.device_get(state), flat, [0])
    import io
    buf = io.BytesIO()
    np.savez(buf, **flat)
    write_atomic(os.path.join(path, _ARRAYS), buf.getvalue())
    manifest = {"format_version": FORMAT_VERSION,
                "skeleton": skeleton,
                "arrays_sha256": sha256_file(os.path.join(path, _ARRAYS)),
                "extra": extra if extra is not None else {}}
    write_atomic_json(os.path.join(path, _MANIFEST), manifest, indent=1)


def load_state(path: str) -> Tuple[Any, Dict[str, Any]]:
    """Load and verify a ``save_state`` snapshot -> ``(state, extra)``.

    Raises ``CheckpointCorruptError`` on any integrity failure: missing
    manifest or arrays, checksum mismatch (torn/corrupted payload), or
    an undecodable skeleton."""
    man_path = os.path.join(path, _MANIFEST)
    arr_path = os.path.join(path, _ARRAYS)
    if not os.path.exists(man_path):
        raise CheckpointCorruptError(
            f"{path}: no manifest (half-written or not a checkpoint)")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptError(f"{path}: unreadable manifest: {e}")
    if manifest.get("format_version") != FORMAT_VERSION:
        raise CheckpointCorruptError(
            f"{path}: unsupported format_version "
            f"{manifest.get('format_version')!r} (want {FORMAT_VERSION})")
    if not os.path.exists(arr_path):
        raise CheckpointCorruptError(f"{path}: missing {_ARRAYS}")
    digest = sha256_file(arr_path)
    if digest != manifest.get("arrays_sha256"):
        raise CheckpointCorruptError(
            f"{path}: checksum mismatch for {_ARRAYS} (stored "
            f"{manifest.get('arrays_sha256')!r}, computed {digest!r}) — "
            f"torn or corrupted checkpoint")
    try:
        with np.load(arr_path) as data:
            flat = {k: data[k] for k in data.files}
        state = _decode(manifest["skeleton"], flat)
    except (KeyError, ValueError, OSError) as e:
        raise CheckpointCorruptError(f"{path}: undecodable payload: {e}")
    return state, manifest.get("extra", {})


def is_valid_checkpoint(path: str) -> bool:
    """Cheap full-integrity probe (manifest + checksum + decode)."""
    try:
        load_state(path)
        return True
    except CheckpointCorruptError:
        return False


# -- legacy (params, opt_state, step) API ------------------------------

def _treedef_str(tree: Any) -> str:
    return str(jax.tree_util.tree_structure(tree))


def save_checkpoint(path: str, params: Any, opt_state: Optional[Any] = None,
                    step: int = 0, extra: Optional[Dict] = None) -> None:
    """Snapshot ``(params, opt_state, step)`` under directory ``path``
    (atomic + checksummed; see module docstring)."""
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    meta = {"step": int(step), "extra": extra or {},
            "params_treedef": _treedef_str(params)}
    if opt_state is not None:
        meta["opt_treedef"] = _treedef_str(opt_state)
    save_state(path, state, extra=meta)


def _restore_like(like: Any, got: Any, path: str) -> Any:
    """Rebuild ``got`` (a decoded v2 state) into the container types of
    the template ``like`` (namedtuples, custom orders), validating
    structure, shape and **dtype** at every leaf — a mismatch raises
    with the offending '/'-joined key path."""
    if like is None:
        if got is not None:
            raise ValueError(f"structure mismatch at {path or '<root>'}: "
                             f"checkpoint has a value where the template "
                             f"has None")
        return None
    if isinstance(like, dict):
        if not isinstance(got, dict):
            raise ValueError(f"structure mismatch at {path or '<root>'}: "
                             f"template dict vs checkpoint "
                             f"{type(got).__name__}")
        if sorted(got) != sorted(str(k) for k in like):
            raise ValueError(
                f"structure mismatch at {path or '<root>'}: template keys "
                f"{sorted(str(k) for k in like)} vs checkpoint keys "
                f"{sorted(got)}")
        return {k: _restore_like(v, got[str(k)], f"{path}/{k}")
                for k, v in like.items()}
    if isinstance(like, (tuple, list)):
        if not isinstance(got, (tuple, list)) or len(got) != len(like):
            raise ValueError(f"structure mismatch at {path or '<root>'}: "
                             f"template {type(like).__name__} of "
                             f"{len(like)} vs checkpoint "
                             f"{type(got).__name__}")
        items = [_restore_like(v, g, f"{path}/{i}")
                 for i, (v, g) in enumerate(zip(like, got))]
        if isinstance(like, tuple):
            # preserve namedtuple classes from the template
            return type(like)(*items) if hasattr(like, "_fields") \
                else tuple(items)
        return items
    # leaf
    like_arr = np.asarray(like)
    got_arr = np.asarray(got)
    if tuple(got_arr.shape) != tuple(like_arr.shape):
        raise ValueError(f"shape mismatch for {path or '<root>'}: "
                         f"checkpoint {tuple(got_arr.shape)} vs template "
                         f"{tuple(like_arr.shape)}")
    if got_arr.dtype != like_arr.dtype:
        raise ValueError(f"dtype mismatch for {path or '<root>'}: "
                         f"checkpoint {got_arr.dtype} vs template "
                         f"{like_arr.dtype} (refusing to cast silently)")
    return jnp.asarray(got_arr)


def load_checkpoint(path: str, params_like: Any,
                    opt_like: Optional[Any] = None
                    ) -> Tuple[Any, Optional[Any], int]:
    """Restore into the structure of ``params_like``.

    Validates the stored params treedef against the template's and every
    leaf's shape AND dtype (raising with the offending key path) on top
    of the v2 integrity checks (checksum, manifest)."""
    state, meta = load_state(path)
    want = _treedef_str(params_like)
    stored = meta.get("params_treedef")
    if stored is not None and stored != want:
        raise ValueError(
            f"params treedef mismatch: checkpoint stored {stored} but the "
            f"restore template is {want}")
    params = _restore_like(params_like, state["params"], "params")
    opt_state = None
    if opt_like is not None:
        if "opt" not in state:
            raise ValueError("checkpoint has no opt state but opt_like "
                             "was provided")
        opt_state = _restore_like(opt_like, state["opt"], "opt")
    return params, opt_state, int(meta["step"])


# -- per-round checkpoint management -----------------------------------

_ROUND_RE = re.compile(r"^round_(\d{6,})$")


class RoundCheckpointer:
    """A directory of per-round ``save_state`` snapshots with cadence,
    retention and corrupt-skip recovery.

    Layout: ``directory/round_NNNNNN/{arrays.npz,manifest.json}``.  Each
    snapshot is internally atomic (see ``save_state``); ``latest_good``
    walks rounds newest-first and skips anything that fails integrity
    checks with a ``CheckpointCorruptWarning`` — a kill mid-save or a
    corrupted payload costs at most the rounds since the previous good
    snapshot, never a silent load of bad state."""

    def __init__(self, directory: str, every: int = 1, keep: int = 3):
        if every < 1:
            raise ValueError(f"checkpoint every must be >= 1: {every}")
        if keep < 1:
            raise ValueError(f"checkpoint keep must be >= 1: {keep}")
        self.directory = os.fspath(directory)
        self.every = int(every)
        self.keep = int(keep)

    def due(self, rnd: int) -> bool:
        """True when round ``rnd`` (0-based) ends a cadence window."""
        return (rnd + 1) % self.every == 0

    def path_for(self, rnd: int) -> str:
        return os.path.join(self.directory, f"round_{rnd:06d}")

    def rounds_on_disk(self) -> List[int]:
        """Round indices with snapshot directories, ascending (no
        integrity check)."""
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            m = _ROUND_RE.match(name)
            if m and os.path.isdir(os.path.join(self.directory, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def save_round(self, rnd: int, state: Any,
                   extra: Optional[Dict[str, Any]] = None) -> str:
        """Snapshot round ``rnd`` and prune snapshots beyond ``keep``."""
        path = self.path_for(rnd)
        save_state(path, state, extra=extra)
        for old in self.rounds_on_disk()[:-self.keep]:
            shutil.rmtree(self.path_for(old), ignore_errors=True)
        return path

    def latest_good(self) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        """``(round, state, extra)`` of the newest snapshot that passes
        integrity checks, skipping corrupt ones with a warning; ``None``
        when no good snapshot exists."""
        for rnd in reversed(self.rounds_on_disk()):
            try:
                state, extra = load_state(self.path_for(rnd))
                return rnd, state, extra
            except CheckpointCorruptError as e:
                warnings.warn(
                    f"skipping corrupt checkpoint {self.path_for(rnd)}: "
                    f"{e}", CheckpointCorruptWarning, stacklevel=2)
        return None

    def clear(self) -> None:
        """Remove every snapshot (a finished run owes the disk nothing)."""
        if os.path.isdir(self.directory):
            shutil.rmtree(self.directory, ignore_errors=True)
