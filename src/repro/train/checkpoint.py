"""Checkpointing: flat-key npz snapshots of arbitrary param pytrees.

Host-local (single-process) persistence.  On a real multi-host pod this
would be an Orbax/ocdbt store; the on-disk format here is deliberately
simple: each leaf saved under its '/'-joined key path, plus a JSON
manifest carrying pytree structure and step metadata.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params: Any, opt_state: Optional[Any] = None,
                    step: int = 0, extra: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    treedef_p = jax.tree_util.tree_structure(params)
    manifest = {"step": step, "extra": extra or {},
                "params_treedef": str(treedef_p)}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, params_like: Any,
                    opt_like: Optional[Any] = None
                    ) -> Tuple[Any, Optional[Any], int]:
    """Restore into the structure of ``params_like`` (shape/dtype checked)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    def restore(prefix: str, like: Any) -> Any:
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_, leaf in flat_like[0]:
            key = prefix + "/".join(
                str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                for p in path_)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(flat_like[1], leaves)

    params = restore("params/", params_like)
    opt_state = restore("opt/", opt_like) if opt_like is not None else None
    return params, opt_state, int(manifest["step"])
