"""Version-compatibility shims for jax API drift.

Keep every try/except-import of a moved jax symbol here so call sites
stay clean and the fallbacks can't drift apart.
"""
from __future__ import annotations

try:                                     # jax >= 0.5
    from jax import shard_map
except ImportError:                      # jax 0.4.x
    from jax.experimental.shard_map import shard_map

__all__ = ["shard_map"]
