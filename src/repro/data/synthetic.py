"""Deterministic synthetic 10-class 28x28 image dataset (MNIST stand-in).

MNIST cannot be downloaded in this offline container (noted in DESIGN.md
§2).  This generator produces a learnable digits-like problem with the
same cardinalities: class-conditional low-frequency prototypes (7x7
Gaussian fields bilinearly upsampled to 28x28) plus per-sample spatial
jitter and pixel noise.  A 2-conv CNN reaches >95% test accuracy on the
i.i.d. version within a few epochs, leaving headroom for the paper's
non-i.i.d. degradation effects to show.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

NUM_CLASSES = 10
IMAGE_SIZE = 28
_PROTO_RES = 7


def _upsample(x: np.ndarray, size: int) -> np.ndarray:
    """Bilinear upsample (H,W) -> (size,size)."""
    h, w = x.shape
    yi = np.linspace(0, h - 1, size)
    xi = np.linspace(0, w - 1, size)
    y0 = np.floor(yi).astype(int)
    x0 = np.floor(xi).astype(int)
    y1 = np.minimum(y0 + 1, h - 1)
    x1 = np.minimum(x0 + 1, w - 1)
    wy = (yi - y0)[:, None]
    wx = (xi - x0)[None, :]
    return ((1 - wy) * (1 - wx) * x[np.ix_(y0, x0)]
            + (1 - wy) * wx * x[np.ix_(y0, x1)]
            + wy * (1 - wx) * x[np.ix_(y1, x0)]
            + wy * wx * x[np.ix_(y1, x1)])


def class_prototypes(seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    protos = []
    for _ in range(NUM_CLASSES):
        low = rng.normal(size=(_PROTO_RES, _PROTO_RES))
        img = _upsample(low, IMAGE_SIZE)
        img = (img - img.min()) / (img.max() - img.min() + 1e-9)
        protos.append(img)
    return np.stack(protos).astype(np.float32)          # (10, 28, 28)


def make_dataset(n_per_class: int, seed: int = 0,
                 noise: float = 0.35) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (N,28,28,1) float32 in [0,1]-ish, labels (N,) int32),
    class-balanced, deterministic in ``seed``."""
    rng = np.random.default_rng(seed + 1)
    protos = class_prototypes(seed)
    images, labels = [], []
    for c in range(NUM_CLASSES):
        base = protos[c]
        for _ in range(n_per_class):
            dy, dx = rng.integers(-2, 3, size=2)
            img = np.roll(np.roll(base, dy, axis=0), dx, axis=1)
            img = img * rng.uniform(0.7, 1.3) + rng.normal(
                scale=noise, size=base.shape)
            images.append(img)
            labels.append(c)
    images = np.stack(images)[..., None].astype(np.float32)
    labels = np.asarray(labels, np.int32)
    perm = rng.permutation(len(labels))
    return images[perm], labels[perm]


def train_test_split(images: np.ndarray, labels: np.ndarray,
                     test_frac: float = 0.15, seed: int = 0):
    rng = np.random.default_rng(seed + 2)
    n = len(labels)
    perm = rng.permutation(n)
    n_test = int(n * test_frac)
    te, tr = perm[:n_test], perm[n_test:]
    return (images[tr], labels[tr]), (images[te], labels[te])
