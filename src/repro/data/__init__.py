from repro.data.synthetic import (class_prototypes, make_dataset,
                                  train_test_split, NUM_CLASSES, IMAGE_SIZE)
from repro.data.lm import SyntheticLM, shard_batch

__all__ = [
    "class_prototypes", "make_dataset", "train_test_split",
    "NUM_CLASSES", "IMAGE_SIZE", "SyntheticLM", "shard_batch",
]
