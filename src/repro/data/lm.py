"""Synthetic token pipeline for the large-architecture training examples.

Deterministic Zipf-distributed token stream with a first-order Markov
structure (so there is learnable signal), chunked into (batch, seq)
next-token-prediction batches.  ``shard_batch`` places a host batch onto
the active mesh according to the batch sharding rules.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


class SyntheticLM:
    def __init__(self, vocab_size: int, seed: int = 0,
                 zipf_a: float = 1.2, markov_weight: float = 0.5):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        self.markov_weight = markov_weight
        # a cheap deterministic successor table: tok -> preferred next
        self.succ = (np.arange(vocab_size) * 2654435761 % vocab_size)

    def stream(self, n: int) -> np.ndarray:
        base = self.rng.choice(self.vocab, size=n, p=self.p)
        take_succ = self.rng.random(n) < self.markov_weight
        out = base.copy()
        out[1:] = np.where(take_succ[1:], self.succ[out[:-1]], base[1:])
        return out.astype(np.int32)

    def batches(self, batch: int, seq: int,
                cfg: Optional[ArchConfig] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            toks = self.stream(batch * (seq + 1)).reshape(batch, seq + 1)
            b: Dict[str, np.ndarray] = {
                "tokens": toks[:, :-1],
                "targets": toks[:, 1:],
                "mask": np.ones((batch, seq), np.float32),
            }
            if cfg is not None and cfg.family == "audio":
                b["frames"] = self.rng.normal(
                    size=(batch, cfg.encoder_seq, cfg.d_model)).astype(
                        np.float32)
            if cfg is not None and cfg.family == "vlm":
                p = cfg.num_prefix_tokens
                b["prefix"] = self.rng.normal(
                    size=(batch, p, cfg.d_model)).astype(np.float32)
                b["tokens"] = b["tokens"][:, : seq - p]
                b["targets"] = b["targets"][:, : seq - p]
                b["mask"] = b["mask"][:, : seq - p]
            yield b


def shard_batch(batch: Dict[str, np.ndarray], mesh, shardings) -> Dict:
    """Place a host batch onto the mesh with the given NamedSharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, shardings)
