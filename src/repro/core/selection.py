"""Client-selection schemes (paper §4.1/Fig. 1).

- ``dcs_select``        — the paper's contribution: each vehicle broadcasts
  its evaluation to DSRC neighbours (within ``comm_range``) iff it clears
  ``E_tau``, and elects itself iff it is in the top-m of its neighbourhood
  table (Alg. 1).  No server involvement.
- ``ccs_fuzzy_select``  — [16]'s scheme: evaluations are computed locally,
  uploaded, and the *server* picks the global top-n.
- ``ccs_random_select`` — classical CCS baseline: server picks n uniformly
  among participants whose state it maintains.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def dcs_select(pos: jax.Array, evals: jax.Array, *, comm_range: float = 200.0,
               top_m: int = 2, e_tau: float = 30.0,
               impl: Optional[str] = None) -> jax.Array:
    """Distributed election.  pos (N,) road positions, evals (N,) fuzzy
    evaluations.  Returns int32 mask (N,), 1 = self-elected client."""
    return kops.neighbor_elect(pos, evals, comm_range=comm_range,
                               top_m=top_m, e_tau=e_tau, impl=impl)


def dcs_select_windowed(pos: jax.Array, evals: jax.Array, *,
                        comm_range: float = 200.0, top_m: int = 2,
                        e_tau: float = 30.0, window: int = 64,
                        impl: Optional[str] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Windowed distributed election: O(N * window) via a position-sorted
    sweep instead of the O(N^2) pairwise table.  Returns ``(mask (N,)
    int32, overflow () int32)`` — the mask is bit-identical to
    ``dcs_select`` whenever ``overflow == 0``; on overflow the caller
    falls back to the dense election."""
    return kops.neighbor_elect_windowed(pos, evals, comm_range=comm_range,
                                        top_m=top_m, e_tau=e_tau,
                                        window=window, impl=impl)


def ccs_fuzzy_select(evals: jax.Array, n_clients: int) -> jax.Array:
    """Server-side top-n on uploaded evaluations -> int32 mask (N,)."""
    n = evals.shape[0]
    _, idx = jax.lax.top_k(evals, min(n_clients, n))
    return jnp.zeros((n,), jnp.int32).at[idx].set(1)


def ccs_random_select(key: jax.Array, n_participants: int,
                      n_clients: int) -> jax.Array:
    """Uniform server-side selection -> int32 mask (N,)."""
    idx = jax.random.choice(key, n_participants,
                            (min(n_clients, n_participants),), replace=False)
    return jnp.zeros((n_participants,), jnp.int32).at[idx].set(1)


def selection_stats(mask: jax.Array, evals: jax.Array) -> dict:
    n_sel = mask.sum()
    return {
        "n_selected": n_sel,
        "mean_eval_selected": jnp.where(
            n_sel > 0, (evals * mask).sum() / jnp.maximum(n_sel, 1), 0.0),
        "mean_eval_all": evals.mean(),
    }
