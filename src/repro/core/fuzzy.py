"""The multi-objective fuzzy evaluator (paper §5).

Mamdani inference over four normalized inputs — SQ, TA, CC, LF — with
3 Gaussian membership functions per variable (Fig. 4), the 81-rule base
of ``core.rules``, max-aggregation into the 9 output levels L0..L8 and
centre-of-gravity defuzzification (Eq. 9) over singleton level centers on
the paper's [0, 100] output scale.

The evaluator is *local*: each participant computes only its own
evaluation from locally observable state.  ``FuzzyEvaluator.evaluate`` is
nevertheless batched (P, 4) because simulation evaluates all participants
at once, and because at IoV scale this is the bulk workload the
``kernels/fuzzy_eval.py`` Pallas kernel accelerates.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rules import build_rule_table, NUM_OUT
from repro.kernels import ops as kops


def default_level_centers() -> jnp.ndarray:
    """L0..L8 singleton centers on the paper's [0,100] evaluation scale."""
    return jnp.linspace(0.0, 100.0, NUM_OUT)


@dataclass
class FuzzyEvaluatorConfig:
    # Gaussian membership (means/sigmas per variable x level); Fig. 4 puts
    # the three functions at low/mid/high of the normalized [0,1] range,
    # with the mid function centred at the historical mean (dashed line).
    means: np.ndarray = field(default_factory=lambda: np.tile(
        np.array([0.15, 0.5, 0.85], np.float32), (4, 1)))
    sigmas: np.ndarray = field(default_factory=lambda: np.full(
        (4, 3), 0.18, np.float32))
    e_tau: float = 30.0          # broadcast threshold E_tau (Alg. 1)


class FuzzyEvaluator:
    """Batched Mamdani evaluator.  ``impl``: jnp | pallas | oracle."""

    def __init__(self, cfg: Optional[FuzzyEvaluatorConfig] = None,
                 impl: Optional[str] = None):
        self.cfg = cfg or FuzzyEvaluatorConfig()
        self.impl = impl
        self.rule_table, self.rule_levels = build_rule_table()
        self.level_centers = default_level_centers()

    # -- normalization (Eq. 8) --------------------------------------------
    @staticmethod
    def normalize(values: jax.Array, maxima: jax.Array) -> jax.Array:
        """value / max(variable) — each column scaled to [0, 1]."""
        return jnp.clip(values / jnp.maximum(maxima, 1e-9), 0.0, 1.0)

    # -- calibration from history (§5.3: bounds from historical records) --
    def calibrate(self, history: np.ndarray) -> None:
        """history: (num_obs, 4) of normalized past observations.  Centers
        the three membership functions on the 10th/50th/90th percentiles,
        matching the paper's 'bound of each linguistic is defined through
        historical records'."""
        pct = np.percentile(history, [10, 50, 90], axis=0).T  # (4,3)
        self.cfg.means = pct.astype(np.float32)
        spread = np.maximum((pct[:, 2] - pct[:, 0]) / 4.0, 0.05)
        self.cfg.sigmas = np.tile(spread[:, None], (1, 3)).astype(np.float32)

    # -- inference ----------------------------------------------------------
    def evaluate(self, x: jax.Array) -> jax.Array:
        """x: (P, 4) normalized [SQ, TA, CC, LF] -> evaluations (P,) on
        [0, 100]."""
        return kops.fuzzy_eval(
            x, jnp.asarray(self.cfg.means), jnp.asarray(self.cfg.sigmas),
            self.rule_table, self.rule_levels, self.level_centers,
            impl=self.impl)

    def evaluate_raw(self, x_raw: jax.Array) -> jax.Array:
        """x_raw: (P, 4) *raw* feature columns — Eq. 8 per-column
        max-scaling is applied inside the kernel (``normalize=True``).
        Object-level convenience mirroring the staged ``evaluate`` stage
        (``fl/pipeline.py``, which passes its own statics straight to
        ``kops.fuzzy_eval``); both share the single kernel entry point,
        and tests/test_fuzzy.py pins them interchangeable."""
        return kops.fuzzy_eval(
            x_raw, jnp.asarray(self.cfg.means), jnp.asarray(self.cfg.sigmas),
            self.rule_table, self.rule_levels, self.level_centers,
            impl=self.impl, normalize=True)

    def level_of(self, evaluation: jax.Array) -> jax.Array:
        """Nearest output level L0..L8 for a defuzzified value."""
        return jnp.argmin(
            jnp.abs(evaluation[..., None] - self.level_centers), axis=-1)
