"""Selection protocols as mesh collectives (shard_map).

This is the TPU-native restatement of the paper's communication claim.
Participants are sharded over the ``data`` axis as contiguous road
segments.  Three protocols, in decreasing communication cost:

- ``ccs_state_gather``   — classical CFL: the *full state vector* of every
  participant is gathered to the (replicated) server: one all-gather of
  (N, state_dim) floats.
- ``ccs_fuzzy_gather``   — CFL-fuzzy [16]: evaluation happens locally, so
  only the scalar evaluation is gathered: one all-gather of (N,) floats.
- ``dcs_neighbor_exchange`` — the paper's scheme: each shard exchanges its
  boundary window with its two road-adjacent shards only (two
  collective-permutes of (W,) floats), then elects locally.  Communication
  is O(W) per device, *independent of N* — the Eq. 5 elimination.

``benchmarks/bench_selection_collectives.py`` lowers all three and counts
collective bytes in the compiled HLO.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.fuzzy import FuzzyEvaluator


def _shmap(f, mesh, in_specs, out_specs):
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs)


def _elect_block(pos_i, ev_i, idx_i, pos_all, ev_all, idx_all, *,
                 comm_range: float, top_m: int, e_tau: float):
    """Election for a block of vehicles against a candidate window."""
    d = jnp.abs(pos_i[:, None] - pos_all[None, :])
    valid = (d <= comm_range) & (ev_all[None, :] >= e_tau)
    better = (ev_all[None, :] > ev_i[:, None]) | (
        (ev_all[None, :] == ev_i[:, None]) & (idx_all[None, :] < idx_i[:, None]))
    n_better = (valid & better).sum(axis=1)
    return ((ev_i >= e_tau) & (n_better < top_m)).astype(jnp.int32)


# --------------------------------------------------------------------------

def make_ccs_state_gather(mesh: Mesh, evaluator: FuzzyEvaluator,
                          n_clients: int, state_dim: int,
                          axis: str = "data") -> Callable:
    """states (N, state_dim) sharded -> selection mask (N,) sharded.

    The server (replicated computation) receives every participant's raw
    state, evaluates, sorts, selects — the CFL scheme of Fig. 1a.
    """
    def body(states):
        full = jax.lax.all_gather(states, axis, axis=0, tiled=True)
        feats = full[:, :4]                      # SQ, TA, CC, LF
        evals = evaluator.evaluate(feats)
        n = evals.shape[0]
        _, top = jax.lax.top_k(evals, n_clients)
        mask = jnp.zeros((n,), jnp.int32).at[top].set(1)
        i = jax.lax.axis_index(axis)
        blk = states.shape[0]
        return jax.lax.dynamic_slice_in_dim(mask, i * blk, blk)

    return _shmap(body, mesh, in_specs=P(axis), out_specs=P(axis))


def make_ccs_fuzzy_gather(mesh: Mesh, n_clients: int,
                          axis: str = "data") -> Callable:
    """evals (N,) sharded (computed locally) -> mask (N,) sharded.
    Only the scalar evaluations travel — Fig. 1b."""
    def body(evals):
        full = jax.lax.all_gather(evals, axis, axis=0, tiled=True)
        n = full.shape[0]
        _, top = jax.lax.top_k(full, n_clients)
        mask = jnp.zeros((n,), jnp.int32).at[top].set(1)
        i = jax.lax.axis_index(axis)
        blk = evals.shape[0]
        return jax.lax.dynamic_slice_in_dim(mask, i * blk, blk)

    return _shmap(body, mesh, in_specs=P(axis), out_specs=P(axis))


def make_dcs_neighbor_exchange(mesh: Mesh, *, comm_range: float = 200.0,
                               top_m: int = 2, e_tau: float = 30.0,
                               window: int = 0,
                               axis: str = "data") -> Callable:
    """(pos (N,), evals (N,)) sharded -> mask (N,) sharded.

    Each shard sends only its boundary ``window`` (defaults to the whole
    shard block) to the left and right road-adjacent shards via
    collective_permute — communication O(window), independent of N.
    """
    n_shards = mesh.shape[axis]

    def body(pos, evals):
        blk = pos.shape[0]
        w = window or blk
        base = jax.lax.axis_index(axis) * blk
        idx = base + jnp.arange(blk, dtype=jnp.int32)

        if n_shards == 1:                      # degenerate: no neighbours
            return _elect_block(pos, evals, idx, pos, evals, idx,
                                comm_range=comm_range, top_m=top_m,
                                e_tau=e_tau)

        right_perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        left_perm = [(i, (i - 1) % n_shards) for i in range(n_shards)]

        def send(x_slice, perm):
            return jax.lax.ppermute(x_slice, axis, perm)

        # my right edge -> right neighbour's left window, and vice versa
        from_left = tuple(send(z[-w:], right_perm)
                          for z in (pos, evals, idx.astype(jnp.float32)))
        from_right = tuple(send(z[:w], left_perm)
                           for z in (pos, evals, idx.astype(jnp.float32)))

        cand_pos = jnp.concatenate([from_left[0], pos, from_right[0]])
        cand_ev = jnp.concatenate([from_left[1], evals, from_right[1]])
        cand_idx = jnp.concatenate([from_left[2], idx.astype(jnp.float32),
                                    from_right[2]]).astype(jnp.int32)
        return _elect_block(pos, evals, idx, cand_pos, cand_ev, cand_idx,
                            comm_range=comm_range, top_m=top_m, e_tau=e_tau)

    return _shmap(body, mesh, in_specs=(P(axis), P(axis)),
                  out_specs=P(axis))
