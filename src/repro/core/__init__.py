"""The paper's primary contribution: distributed client selection with a
multi-objective fuzzy evaluator, plus the communication-overhead models and
the mesh-collective restatement of the selection protocols."""
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.core.rules import build_rule_table, verify_anchors
from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select, selection_stats)

__all__ = [
    "FuzzyEvaluator", "FuzzyEvaluatorConfig", "build_rule_table",
    "verify_anchors", "ccs_fuzzy_select", "ccs_random_select", "dcs_select",
    "selection_stats",
]
