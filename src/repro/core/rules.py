"""The 81-item fuzzy rule base (paper Table 2).

The paper publishes 9 of the 81 rows (rules 1-3, 52-54, 79-81) and states
the rest were tuned empirically.  We reconstruct the full table from two
principles that reproduce *all nine published anchors exactly*:

1. **Additive contribution** — when the vehicle can upload (TA or CC not
   both at their worst level), the consequent level is the sum of the four
   linguistic indices (each in {0,1,2}), giving L0..L8:
       rule 1  (Suff,High,Strong,Greater)  2+2+2+2 = L8  ✓
       rule 2  (Avg, High,Strong,Greater)  1+2+2+2 = L7  ✓
       rule 3  (Short,High,Strong,Greater) 0+2+2+2 = L6  ✓
2. **Upload bottleneck** — when TA=Poor AND CC=Weak the model likely
   cannot be uploaded before the deadline, so only dataset quality
   matters, multiplicatively: level = SQ_idx * LF_idx:
       rule 52 (Suff,Poor,Weak,Middle)  2*1 = L2  ✓
       rule 53 (Avg, Poor,Weak,Middle)  1*1 = L1  ✓
       rule 54 (Short,Poor,Weak,Middle) 0*1 = L0  ✓
       rules 79-81 (*,Poor,Weak,Smaller)  *  = L0  ✓✓✓

The table is monotone: raising any input level never lowers the output
level (property-tested in tests/test_fuzzy.py).

Variable order and linguistics follow the paper:
  SQ (sample quantity):          shortage / average / sufficient
  TA (throughput available):     poor / middle / good
  CC (computational capability): weak / middle / strong
  LF (loss function):            smaller / middle / greater
Index 0 is always the worst level, 2 the best ("greater loss" = more
dataset diversity = better, per §5.3).
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

NUM_VARS = 4
NUM_LEVELS = 3
NUM_OUT = 9
VAR_NAMES = ("SQ", "TA", "CC", "LF")
LINGUISTICS = {
    "SQ": ("shortage", "average", "sufficient"),
    "TA": ("poor", "middle", "good"),
    "CC": ("weak", "middle", "strong"),
    "LF": ("smaller", "middle", "greater"),
}


def consequent(sq: int, ta: int, cc: int, lf: int) -> int:
    """Output level L0..L8 for one antecedent combination."""
    if ta == 0 and cc == 0:               # upload bottleneck
        return sq * lf
    return sq + ta + cc + lf              # additive contribution


def build_rule_table() -> Tuple[np.ndarray, np.ndarray]:
    """Returns (rule_table (81,4) int32, rule_levels (81,) int32).

    Enumeration order matches the paper's Table 2 exactly: within each
    consecutive triplet SQ descends (sufficient, average, shortage), and
    across triplets CC, then TA, then LF descend — this places the paper's
    published rows (1-3, 52-54, 79-81) at the same indices with the same
    antecedents:  rule r-1 = (lf, ta, cc, sq) =
    (2 - (r-1)//27, 2 - ((r-1)%27)//9, 2 - ((r-1)%9)//3, 2 - (r-1)%3).
    """
    rows, levels = [], []
    for lf, ta, cc, sq in itertools.product(range(2, -1, -1), repeat=4):
        rows.append((sq, ta, cc, lf))
        levels.append(consequent(sq, ta, cc, lf))
    return (np.asarray(rows, np.int32), np.asarray(levels, np.int32))


# Published anchor rows (1-indexed rule number -> expected level).
PAPER_ANCHORS = {
    1: 8, 2: 7, 3: 6,          # Suff/Avg/Short, High, Strong, Greater
    52: 2, 53: 1, 54: 0,       # Suff/Avg/Short, Poor, Weak, Middle
    79: 0, 80: 0, 81: 0,       # Suff/Avg/Short, Poor, Weak, Smaller
}


def verify_anchors() -> bool:
    table, levels = build_rule_table()
    # paper enumerates (SQ outer desc, then TA desc, CC desc, LF desc)
    for rule_no, want in PAPER_ANCHORS.items():
        if int(levels[rule_no - 1]) != want:
            return False
    return True
