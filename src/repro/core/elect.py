"""Windowed DCS neighbour election (ISSUE 9 tentpole).

The paper's Alg. 1 only ever compares a vehicle against neighbours
within ``comm_range``, but the reference election
(``kernels/ref.py::neighbor_elect_ref``) — and the sharded prefix's
full-``(N,)`` ``all_gather`` seam built on it — pay O(N^2) compares and
O(N) collective bytes regardless of how local the physics is.  This
module exploits the locality: **sorted by road position, the in-range
neighbours of any vehicle form a contiguous index run** (distance is
linear ``|x_i - x_j|``), so a window of ``W`` sorted neighbours per side
covers every comparison that can matter, and the per-vehicle cost drops
to O(W).

Everything here is *exact or flagged*: the counting compares are the
bitwise-identical ``(d <= comm_range)`` / eval / index-tie predicates of
the reference on the same float values, and whenever a fixed window or
buffer capacity could have truncated a comparison that the reference
would make, a runtime ``overflow`` flag is raised instead of silently
diverging.  Callers (the staged prefix drivers) re-run the affected
round through the gather election on overflow — so the windowed masks
are bit-identical to the full election whenever they are used at all.

Three layers share the core:

- ``windowed_elect``      — single-device: sort, blocked window counts,
  scatter back (the O(N*W) replacement for the O(N^2) kernel sweep);
- ``ring_halo_elect``     — inside ``shard_map``: re-bucket clients into
  road-segment shards with one tiled ``all_to_all``, exchange fixed-
  width boundary halos with the ``h = ceil(comm_range / segment)``
  adjacent shards over a ``ppermute`` ring (wrap-around ring topology;
  the wrapped strips are masked empty because road distance is linear),
  elect on local+halo candidates, route the masks back through the
  inverse ``all_to_all``.  Per-device compare cost O(N/K * W); the halo
  exchange itself is O(h * W) bytes — flat in N at fixed ``comm_range``
  and density (the O(N/K) re-bucketing shuffle is layout movement, not
  election traffic, and shrinks with the mesh);
- ``sharded_topk_mask``   — the CCS quota on a hierarchical top-k
  (local top-k, gather K*k candidates, global top-k) instead of the
  gathered (N,) vector; exact including the lowest-index tie-break.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# far-away / below-threshold sentinels for padded slots (match the
# Pallas dense kernel's padding convention)
SENT_POS = 1e18
SENT_EV = -1e18


def auto_window(n: int, comm_range: float, road_length: float) -> int:
    """Default sorted-neighbour window: 3x the expected one-side
    in-range population (uniform density) plus slack, clamped to the
    fleet.  Generous on purpose — an undersized window only costs a
    gather fallback, an oversized one only compares more zeros."""
    density = n / max(road_length, 1e-9)
    w = int(3.0 * comm_range * density) + 16
    return max(16, min(n, w))


def auto_capacity(shard_n: int, n_shards: int) -> int:
    """Per-(source shard -> road segment) bucket capacity: 2x the
    uniform expectation plus slack.  Clustered fleets can exceed it —
    that raises the overflow flag, never a wrong mask."""
    return min(shard_n, 2 * (-(-shard_n // n_shards)) + 16)


def _counts_block_jnp(sp: jax.Array, se: jax.Array, sg: jax.Array, *,
                      comm_range: float, e_tau: float, n_valid: int,
                      window: int, block: int) -> jax.Array:
    """Blocked better-neighbour counts over sorted arrays (lax.map over
    row blocks keeps the live compare tile at (block, block + 2W))."""
    m = sp.shape[0]
    nb = m // block
    rel = jnp.arange(-window, block + window)

    def one_block(ib):
        rows = ib * block + jnp.arange(block)
        cand = ib * block + rel
        inb = (cand >= 0) & (cand < m)
        cc = jnp.clip(cand, 0, m - 1)
        cp = jnp.where(inb, sp[cc], SENT_POS)
        ce = jnp.where(inb, se[cc], SENT_EV)
        cg = jnp.where(inb, sg[cc], n_valid)
        pi, ei, gi = sp[rows], se[rows], sg[rows]
        d = jnp.abs(pi[:, None] - cp[None, :])
        ok = (d <= comm_range) & (ce[None, :] >= e_tau) \
            & (cg[None, :] < n_valid)
        better = (ce[None, :] > ei[:, None]) | (
            (ce[None, :] == ei[:, None]) & (cg[None, :] < gi[:, None]))
        return jnp.sum((ok & better).astype(jnp.int32), axis=1)

    return jax.lax.map(one_block, jnp.arange(nb)).reshape(m)


def window_coverage(sp: jax.Array, se: jax.Array, sg: jax.Array, *,
                    comm_range: float, e_tau: float, n_valid: int,
                    window: int, need: jax.Array) -> jax.Array:
    """True iff every ``need`` entry's valid in-range neighbours all lie
    within ``window`` sorted slots — i.e. the windowed counts equal the
    full reference counts.  The range bound widens by a float-safety
    margin (position-scaled), so boundary rounding can only *over*-flag
    (a spurious gather fallback), never under-flag (a wrong mask)."""
    m = sp.shape[0]
    if window >= m - 1:
        return jnp.bool_(True)
    real = sg < n_valid
    span = jnp.max(jnp.where(real, jnp.abs(sp), 0.0))
    cr = comm_range + 1e-5 * jnp.maximum(span, 1.0) + 1e-8
    valid = (real & (se >= e_tau)).astype(jnp.int32)
    cum = jnp.cumsum(valid)

    def count_in(a, b):                       # valid entries in [a, b]
        a = jnp.clip(a, 0, m - 1)
        bc = jnp.clip(b, 0, m - 1)
        c = cum[bc] - jnp.where(a > 0, cum[a - 1], 0)
        return jnp.where(b >= a, c, 0)

    idx = jnp.arange(m)
    lo = jnp.searchsorted(sp, sp - cr, side="left")
    hi = jnp.searchsorted(sp, sp + cr, side="right") - 1
    beyond = count_in(lo, idx - window - 1) + count_in(idx + window + 1, hi)
    return ~jnp.any((beyond > 0) & need)


def sorted_window_counts(sp: jax.Array, se: jax.Array, sg: jax.Array, *,
                         comm_range: float, e_tau: float, n_valid: int,
                         window: int, need: Optional[jax.Array] = None,
                         block: int = 128, impl: str = "jnp"
                         ) -> Tuple[jax.Array, jax.Array]:
    """Better-neighbour counts on a position-sorted candidate array.

    ``sp``/``se``/``sg``: (M,) sorted positions / evals / global ids
    (sentinel slots carry pos=``SENT_POS``, ev=``SENT_EV``, id >=
    ``n_valid``).  Returns ``(counts (M,) int32, covered () bool)``:
    ``counts[i]`` applies the reference predicates against the loaded
    window around ``i``; ``covered`` certifies the window saw every
    comparison the full reference would make for the ``need`` entries
    (default: all real entries).  When ``covered`` the counts — and any
    mask derived from them — are bit-identical to the dense reference."""
    m = sp.shape[0]
    w = min(int(window), m)
    b = min(block, max(32, m))
    mp = -(-m // b) * b
    pad = mp - m
    spp = jnp.pad(sp, (0, pad), constant_values=SENT_POS)
    sep = jnp.pad(se, (0, pad), constant_values=SENT_EV)
    sgp = jnp.pad(sg, (0, pad), constant_values=n_valid)
    if impl == "pallas":
        from repro.kernels.neighbor_elect import windowed_counts_pallas
        counts = windowed_counts_pallas(
            spp, sep, sgp, comm_range=comm_range, e_tau=e_tau,
            n_valid=n_valid, window=w, block=b,
            interpret=jax.default_backend() != "tpu")[:m]
    else:
        counts = _counts_block_jnp(spp, sep, sgp, comm_range=comm_range,
                                   e_tau=e_tau, n_valid=n_valid, window=w,
                                   block=b)[:m]
    if need is None:
        need = sg < n_valid
    covered = window_coverage(sp, se, sg, comm_range=comm_range,
                              e_tau=e_tau, n_valid=n_valid, window=w,
                              need=need)
    return counts, covered


def windowed_elect(pos: jax.Array, evals: jax.Array, *, comm_range: float,
                   top_m: int, e_tau: float, window: int,
                   impl: str = "jnp") -> Tuple[jax.Array, jax.Array]:
    """Single-device windowed election: (mask (N,) int32, overflow ()
    int32).  ``overflow == 0`` certifies the mask bit-identical to
    ``neighbor_elect_ref``; the caller falls back to the dense election
    otherwise."""
    n = pos.shape[0]
    order = jnp.argsort(pos)                  # stable: ties keep id order
    sp = pos[order]
    se = evals[order]
    sg = order.astype(jnp.int32)              # global id = the tie-break
    counts, covered = sorted_window_counts(
        sp, se, sg, comm_range=comm_range, e_tau=e_tau, n_valid=n,
        window=window, need=jnp.ones((n,), bool), impl=impl)
    sel = ((se >= e_tau) & (counts < top_m)).astype(jnp.int32)
    mask = jnp.zeros((n,), jnp.int32).at[order].set(sel)
    return mask, (~covered).astype(jnp.int32)


# --------------------------------------------------------------------------
# shard_map interior: segment re-bucketing + ppermute halo ring
# --------------------------------------------------------------------------

def ring_hops(comm_range: float, road_length: float, n_shards: int) -> int:
    """Adjacent-segment hops whose span covers ``comm_range``."""
    segw = road_length / n_shards
    return max(1, int(math.ceil(comm_range / segw)))


def ring_halo_elect(pos: jax.Array, evals: jax.Array, gid: jax.Array,
                    valid: jax.Array, *, axis: str, n: int, n_shards: int,
                    shard_n: int, comm_range: float, top_m: int,
                    e_tau: float, road_length: float, window: int,
                    capacity: int) -> Tuple[jax.Array, jax.Array]:
    """The windowed DCS election inside a ``("clients",)`` shard_map.

    Per device (= road segment owner):

    1. route every local client to its segment's owner with ONE tiled
       ``all_to_all`` of fixed ``(K, capacity)`` buffers (slot overflow
       -> flag);
    2. sort the received bucket by position; pull ``h`` boundary halo
       strips of width ``window`` from each ring neighbour by
       ``ppermute`` (strip overflow -> flag; strips that would wrap the
       road end are masked empty — reference distance is linear);
    3. merge + windowed counts (coverage shortfall -> flag), elect;
    4. inverse ``all_to_all`` routes each client's bit back to its
       owner's slot.

    Returns ``(mask (shard_n,) int32, overflow () int32 — this device's
    local flag; callers pmax it)``.  ``overflow == 0`` on every device
    certifies bit-identity with the gathered dense election."""
    k = n_shards
    segw = road_length / k
    h = ring_hops(comm_range, road_length, k)
    cap = capacity
    w = min(int(window), k * cap)
    i = jax.lax.axis_index(axis)
    # float-safety margin for the segment-boundary thresholds: widening
    # only adds candidates (masked later by the exact distance compare)
    margin = 1e-4 * road_length + 1e-6

    # -- 1. bucket clients by road segment, fixed (K, cap) send slots --
    seg = jnp.clip(jnp.floor(pos * (k / road_length)), 0, k - 1)
    seg = jnp.where(valid, seg.astype(jnp.int32), k)     # dummies drop
    order = jnp.argsort(seg)                             # stable
    sseg = seg[order]
    starts = jnp.searchsorted(sseg, jnp.arange(k))
    rank = jnp.arange(shard_n) - starts[jnp.clip(sseg, 0, k - 1)]
    send_ovf = jnp.any((sseg < k) & (rank >= cap))
    row = jnp.where((sseg < k) & (rank < cap), sseg, k)  # row k = dropped
    col = jnp.clip(rank, 0, cap - 1)

    def scatter(x, fill):
        buf = jnp.full((k + 1, cap), fill, x.dtype)
        return buf.at[row, col].set(x[order])[:k]

    bpos = scatter(pos.astype(jnp.float32), SENT_POS)
    bev = scatter(evals.astype(jnp.float32), SENT_EV)
    bgid = scatter(gid.astype(jnp.int32), n)

    def a2a(x):
        return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)

    rpos, rev, rgid = a2a(bpos), a2a(bev), a2a(bgid)

    # -- 2. sort my segment's bucket, exchange halo strips -------------
    s = k * cap
    fpos, fev, fgid = rpos.reshape(s), rev.reshape(s), rgid.reshape(s)
    border = jnp.argsort(fpos)
    sp, se, sg = fpos[border], fev[border], fgid[border]
    n_real = jnp.searchsorted(sp, SENT_POS / 2.0)

    def suffix_strip(thr):
        """My clients with pos >= thr (capped at ``w``, overflow-flagged)."""
        start = jnp.searchsorted(sp, thr, side="left")
        cnt = jnp.maximum(n_real - start, 0)
        base = jnp.clip(jnp.minimum(start, s - w), 0, s - w)
        j = base + jnp.arange(w)
        ok = (j >= start) & (j < n_real)
        return (jnp.where(ok, jax.lax.dynamic_slice(sp, (base,), (w,)),
                          SENT_POS),
                jnp.where(ok, jax.lax.dynamic_slice(se, (base,), (w,)),
                          SENT_EV),
                jnp.where(ok, jax.lax.dynamic_slice(sg, (base,), (w,)), n),
                cnt > w)

    def prefix_strip(thr):
        """My clients with pos <= thr (capped at ``w``, overflow-flagged)."""
        end = jnp.minimum(jnp.searchsorted(sp, thr, side="right"), n_real)
        ok = jnp.arange(w) < end
        return (jnp.where(ok, sp[:w], SENT_POS),
                jnp.where(ok, se[:w], SENT_EV),
                jnp.where(ok, sg[:w], n),
                end > w)

    strips = []
    strip_ovf = jnp.bool_(False)
    for d in range(1, h + 1):
        # strip for receiver i+d: my suffix within comm_range of their
        # left edge; wrapped receivers (linear road!) get nothing
        rj = i + d
        thr = jnp.where(rj >= k, jnp.float32(SENT_POS),
                        rj * segw - comm_range - margin)
        spb, seb, sgb, so = suffix_strip(thr)
        strip_ovf |= so
        fwd = [(src, (src + d) % k) for src in range(k)]
        strips.append(tuple(jax.lax.ppermute(z, axis, fwd)
                            for z in (spb, seb, sgb)))
        # strip for receiver i-d: my prefix within comm_range of their
        # right edge
        lj = i - d
        thr_hi = jnp.where(lj < 0, jnp.float32(-SENT_POS),
                           (lj + 1) * segw + comm_range + margin)
        spb, seb, sgb, so = prefix_strip(thr_hi)
        strip_ovf |= so
        bwd = [(src, (src - d) % k) for src in range(k)]
        strips.append(tuple(jax.lax.ppermute(z, axis, bwd)
                            for z in (spb, seb, sgb)))

    # -- 3. merge own + halo candidates, windowed election -------------
    mpos = jnp.concatenate([sp] + [st[0] for st in strips])
    mev = jnp.concatenate([se] + [st[1] for st in strips])
    mgid = jnp.concatenate([sg] + [st[2] for st in strips])
    tag = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                           jnp.full(2 * h * w, s, jnp.int32)])
    morder = jnp.argsort(mpos)
    msp, mse, msg, mtag = (mpos[morder], mev[morder], mgid[morder],
                           tag[morder])
    counts, covered = sorted_window_counts(
        msp, mse, msg, comm_range=comm_range, e_tau=e_tau, n_valid=n,
        window=w, need=(mtag < s) & (msg < n))
    sel = ((mse >= e_tau) & (counts < top_m)
           & (msg < n)).astype(jnp.int32)

    # -- 4. scatter back: merged -> bucket slots -> inverse a2a --------
    sel_sorted = jnp.zeros((s,), jnp.int32).at[mtag].set(sel, mode="drop")
    sel_bucket = jnp.zeros((s,), jnp.int32).at[border].set(sel_sorted)
    back = a2a(sel_bucket.reshape(k, cap))    # tiled a2a is an involution
    got = jnp.where((row < k),
                    back[jnp.clip(row, 0, k - 1), col], 0)
    mask = jnp.zeros((shard_n,), jnp.int32).at[order].set(got)
    ovf = (send_ovf | strip_ovf | ~covered).astype(jnp.int32)
    return mask, ovf


def sharded_topk_mask(evals: jax.Array, gid: jax.Array, valid: jax.Array,
                      *, axis: str, n: int, shard_n: int,
                      k_top: int) -> jax.Array:
    """Hierarchical global top-k inside a shard_map: local top-k per
    shard, one tiny ``all_gather`` of the K*k (value, gid) candidates,
    global top-k over the flattened list.

    Exact vs ``lax.top_k`` on the gathered (N,) vector *including* ties:
    ``top_k`` breaks equal values by lowest index, per-shard candidates
    keep ascending local order among ties, and the shard-major flat
    layout makes flat order == gid order among any tied value — so the
    winner set (and hence the mask) is bit-identical."""
    kloc = min(k_top, shard_n)
    ev_m = jnp.where(valid, evals, -jnp.inf)
    v, li = jax.lax.top_k(ev_m, kloc)
    g = gid[li].astype(jnp.int32)
    cv = jax.lax.all_gather(v, axis)          # (K, kloc)
    cg = jax.lax.all_gather(g, axis)
    _, sidx = jax.lax.top_k(cv.reshape(-1), k_top)
    winners = cg.reshape(-1)[sidx]
    mask = (gid[:, None] == winners[None, :]).any(axis=1)
    return (mask & valid).astype(jnp.int32)
