"""Communication-overhead models (paper §4.2, Fig. 2 and Fig. 9).

Two kinds of overhead:
  1. maintaining the active state of all participants (Eq. 5):
         c = N * s * t / tau        [bytes per round]
  2. exchanging the model: broadcast (multicast, constant) + uploads
         m_up = n_clients * model_size.

Fig. 2 (GBoard): byte comparison.  Fig. 9 (Tokyo): *accumulated consumed
time* — every state message pays the full access latency (it's a small
packet), so time ≈ messages x latency + serialized upload time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


# ---- Table 1: the GBoard reference parameters -----------------------------

@dataclass(frozen=True)
class GBoardParams:
    n_participants: int = 1_500_000
    round_period_s: float = 72.0
    model_bytes: float = 1.4e6
    clients_per_round: int = 300
    state_bytes_cfl: float = 100.0
    state_bytes_ccs_fuzzy: float = 30.0


# ---- Table 3: the IoV simulator parameters --------------------------------

@dataclass(frozen=True)
class IoVParams:
    n_participants: int = 3_090_000       # Tokyo registered vehicles [33]
    clients_per_round: int = 1000
    round_period_s: float = 20.0          # deadline of a round
    model_bytes: float = 5.2e6            # the 1.66M-param CNN
    state_bytes_cfl: float = 100.0
    state_bytes_ccs_fuzzy: float = 30.0
    eval_bytes_dcs: float = 30.0          # scalar eval + id, one DSRC pkt
    latency_cloud_s: float = 0.200        # vehicle -> cloud
    latency_dsrc_s: float = 0.040         # vehicle -> vehicle
    uplink_bps_best: float = 10.4e6
    uplink_bps_worst: float = 0.24e6


# The paper's Fig. 2 values (22.5 GB at tau=1 s; crossings at 52 s / 15 s)
# are reproduced by Eq. 5 only with a factor-2 on the state traffic —
# i.e. the paper counts the status message in both directions (update +
# acknowledgement).  1.5e6*100*72 = 10.8 GB; x2 = 21.6 GB ~ 22.5 GB; the
# crossing times scale identically (2*25.7 ~ 52 s, 2*7.7 ~ 15 s).
DUPLEX_FACTOR = 2.0


def state_maintenance_bytes(n: int, state_bytes: float, round_period_s: float,
                            interval_s: float,
                            duplex: float = DUPLEX_FACTOR) -> float:
    """Eq. 5:  c = N * s * t / tau   (bytes of state traffic per round)."""
    return duplex * n * state_bytes * round_period_s / interval_s


def model_upload_bytes(clients: int, model_bytes: float) -> float:
    return clients * model_bytes


def crossing_interval_s(n: int, state_bytes: float, round_period_s: float,
                        clients: int, model_bytes: float,
                        duplex: float = DUPLEX_FACTOR) -> float:
    """Interval tau at which state upkeep equals model-upload bytes."""
    return duplex * n * state_bytes * round_period_s / (clients * model_bytes)


def fig2_curves(intervals_s: np.ndarray,
                p: GBoardParams = GBoardParams()) -> Dict[str, np.ndarray]:
    """Reproduces Fig. 2 (bytes vs state-update interval, GBoard)."""
    cfl = state_maintenance_bytes(p.n_participants, p.state_bytes_cfl,
                                  p.round_period_s, intervals_s)
    fuz = state_maintenance_bytes(p.n_participants, p.state_bytes_ccs_fuzzy,
                                  p.round_period_s, intervals_s)
    up = np.full_like(np.asarray(intervals_s, float),
                      model_upload_bytes(p.clients_per_round, p.model_bytes))
    return {"interval_s": np.asarray(intervals_s, float),
            "cfl_bytes": cfl, "ccs_fuzzy_bytes": fuz, "upload_bytes": up}


def accumulated_time_s(scheme: str, interval_s: float,
                       p: IoVParams = IoVParams()) -> float:
    """Fig. 9: per-round accumulated communication time, all participants.

    CCS / CCS-fuzzy: every participant sends its state to the *cloud*
    every ``interval_s`` (full access latency each, small packet), plus
    the clients' model uploads.
    DCS: evaluations are broadcast to *neighbours over DSRC* (lower
    latency, local range, only above-threshold vehicles — we bound it by
    all N), plus the same model uploads; no state ever goes to the cloud.
    """
    msgs = p.n_participants * p.round_period_s / interval_s
    upload_t = (p.clients_per_round
                * (p.model_bytes * 8.0 / p.uplink_bps_best
                   + p.latency_cloud_s))
    if scheme in ("ccs", "ccs-fuzzy", "cfl"):
        return msgs * p.latency_cloud_s + upload_t
    if scheme == "dcs":
        return msgs * p.latency_dsrc_s + upload_t
    if scheme == "model-only":
        return upload_t
    raise ValueError(scheme)


def fig9_curves(intervals_s: np.ndarray,
                p: IoVParams = IoVParams()) -> Dict[str, np.ndarray]:
    iv = np.asarray(intervals_s, float)
    out = {"interval_s": iv}
    for scheme in ("ccs", "ccs-fuzzy", "dcs", "model-only"):
        out[scheme] = np.array([accumulated_time_s(scheme, t, p) for t in iv])
    return out
