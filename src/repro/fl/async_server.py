"""Event-driven streaming FL server (ISSUE 6 tentpole).

The synchronous drivers in ``fl/rounds.py`` aggregate at a round
barrier: every selected client either lands inside the Eq. 6 deadline
or is discarded.  This module generalizes the PR 5 round-ahead
scheduler into an **event-driven fleet**:

- **churn**: the staged prefix (``fl/pipeline.py``) gates evaluation /
  selection on a mobility-driven coverage window
  (``mobility.coverage_active``) and reports each client's presence at
  its own upload-completion instant — a vehicle that leaves RSU
  coverage mid-training loses its pending update;
- **staleness**: with ``staleness="weighted"`` stragglers past the
  deadline still train; their update lands at a later aggregation tick
  with FedAvg weight scaled by ``timing.staleness_weight`` —
  ``1 / (1 + lambda * delay_rounds)``;
- **cadence**: the server aggregates every ``agg_cadence_s`` simulated
  seconds (default: the round period) instead of at the round barrier.

Tick algebra (all host-side integers; ``P`` is the round period
``deadline_s``, ``T`` the cadence):

    round r spans      [r*P, (r+1)*P)
    update lands at    tick k = ceil(t_done / T)
    tick k fires in    round ceil(k*T / P) - 1
    delay_rounds       = firing round - source round   (>= 0)

Each tick's aggregation is a FedAvg over the updates landing at that
tick, plus — in weighted mode — an **anchor** row: the current global
model carrying the staleness-discounted weight mass
``sum_i w_i * (1 - s_i)``.  A fully fresh tick (every ``s_i = 1``) is
therefore plain FedAvg; a fully stale one leaves the global model
(almost) unchanged, and the update's pull shrinks continuously with
``lambda`` in between.  Drop mode never adds the anchor — it is the
``lambda -> inf`` limit pinned exactly to {1 at deadline, 0 after}.

**Sync parity**: with churn off, staleness "drop" and the cadence at
the round period, every surviving update lands at tick ``r + 1`` —
which fires in round ``r`` — so the event server degenerates to the
round barrier.  That case is detected up front and delegates training
and row assembly to ``FLSimulation`` verbatim, which (together with the
statically-gated churn branch compiling the identical prefix
executable) makes the event server reproduce the serial driver's rows
**bit-identically** (pinned in tests/test_async.py, single-device and
on a forced 4-device clients mesh).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import pipeline
from repro.fl.aggregation import fedavg_masked
from repro.fl.client import evaluate_accuracy_async
from repro.fl.rounds import (build_round_checkpointer, checkpoint_round,
                             resume_rows)
from repro.fl.timing import staleness_weight

# the pool FedAvg must NOT donate: a landing tick can merge stacks that
# were enqueued rounds ago and (in principle) share buffers with other
# ticks' pending entries, so the donated twin in fl/pipeline.py is off
# limits here
_fedavg_pool = jax.jit(lambda merged, weights: fedavg_masked(merged,
                                                             weights))

# rounds-behind histogram bins: delays 0, 1, 2, 3+ (aggregated updates)
_HIST_BINS = 4

# pending-entry scalar fields and their host types (checkpoint restore
# re-coerces through these so a JSON/npz round-trip cannot drift a type)
_ENTRY_SCALARS = {"src": int, "n": int, "delay": int,
                  "anchor": float, "scale": float}


class EventDrivenServer:
    """Streaming aggregation driver wrapping one ``FLSimulation``.

    Duck-types the simulation's driver surface (``_dispatch_training``,
    ``_round_row``, ``finish_round``, ``run``) so the sweep harness and
    the round-ahead scheduler drive it unchanged; the staged selection
    prefix — fused probe, clients-mesh sharding and all — stays on the
    wrapped simulation and keeps compiling the same executables."""

    def __init__(self, sim):
        self.sim = sim
        self.run_cfg = sim.run_cfg
        self.period = float(sim.stage_cfg.timing.deadline_s)
        self.cadence = float(self.run_cfg.agg_cadence_s
                             if self.run_cfg.agg_cadence_s is not None
                             else self.period)
        self.weighted = self.run_cfg.staleness == "weighted"
        # the degenerate event server IS the round barrier: no churn, hard
        # deadline, one tick per round -> delegate to the sync driver
        # verbatim (the bit-parity pin)
        self.sync_equivalent = (self.run_cfg.churn_rate == 0.0
                                and not self.weighted
                                and self.cadence == self.period)
        if not self.sync_equivalent and self.run_cfg.engine != "batched":
            raise ValueError(
                "the event-driven pool path trains through the batched "
                f"engine; engine={self.run_cfg.engine!r} only supports "
                "the sync-equivalent configuration")
        # landing tick -> [(source round, stack/psum partials, ...)]
        self._pending: Dict[int, List[Dict]] = {}
        self._stats: Dict[int, Dict] = {}

    # -- sweep/driver duck-typing surface ------------------------------
    @property
    def params(self):
        return self.sim.params

    @property
    def test_images(self):
        return self.sim.test_images

    @property
    def test_labels(self):
        return self.sim.test_labels

    def selection_state(self, rnd: int) -> Dict[str, jax.Array]:
        return self.sim.selection_state(rnd)

    # -- tick algebra ---------------------------------------------------
    def _tick_round(self, k: int) -> int:
        """The round in which tick ``k`` fires (k*T falls inside it)."""
        return int(math.ceil(k * self.cadence / self.period)) - 1

    def _due_ticks(self, rnd: int) -> List[int]:
        """Pending ticks firing by the end of round ``rnd``, in order."""
        k_max = int(math.floor((rnd + 1) * self.period / self.cadence))
        return sorted(k for k in self._pending if k <= k_max)

    # -- training dispatch ---------------------------------------------
    def _dispatch_training(self, rnd: int, host: Dict) -> None:
        """Enqueue round ``rnd``'s local training into landing-tick
        pools, then fire every aggregation tick due by the round's end.
        Training always starts from the *current* global model (the
        broadcast at round start), so enqueue precedes the tick sweep."""
        if self.sync_equivalent:
            self.sim._dispatch_training(rnd, host)
            return
        self._stats[rnd] = {"n_agg": 0, "n_stale": 0, "eff": 0.0,
                            "hist": [0] * _HIST_BINS}
        self._enqueue_round(rnd, host)
        self._process_due_ticks(rnd)

    def _enqueue_round(self, rnd: int, host: Dict) -> None:
        sim = self.sim
        cfg = sim.cfg
        mask = np.asarray(host["mask"])
        sim._record_participation(mask)
        survivors = np.asarray(host["survivors"]).astype(bool)
        alive = np.asarray(host["alive_at_done"]).astype(bool)
        t_done = np.asarray(host["t_done"], np.float64)
        # weighted mode trains every selected client (stragglers land
        # late, discounted); drop mode keeps the Eq. 6 survivors.  Either
        # way a client out of coverage at its upload instant is lost.
        train_mask = ((mask > 0) if self.weighted else survivors) & alive
        if not train_mask.any():
            return
        land = np.maximum(np.ceil(t_done / self.cadence).astype(np.int64),
                          1)
        keys = sim._round_keys(rnd)
        lam = self.run_cfg.staleness_lambda
        if sim.client_mesh is not None:
            # sharded: one psum'd partial aggregate per landing tick —
            # the per-tick staleness factor folds into the cohort
            # weights at the trainer (weight_scale), the anchor mass is
            # tracked host-side from the same |D_i| the weights use
            for k in np.unique(land[train_mask]):
                bucket = train_mask & (land == k)
                delay = max(0, self._tick_round(int(k)) - rnd)
                s = (staleness_weight(lam, delay) if self.weighted
                     else 1.0)
                trained = pipeline.train_groups_sharded(
                    sim.params, sim.groups, sim._group_steps, bucket,
                    keys, sim.client_mesh, epochs=cfg.local_epochs,
                    batch_size=cfg.batch_size, lr=cfg.lr,
                    prox_mu=cfg.prox_mu, weight_scale=float(s))
                if trained is None:
                    continue
                num, den = trained
                w_data = float(sim.n_valid[bucket].sum())
                self._pending.setdefault(int(k), []).append({
                    "src": rnd, "num": num, "den": den,
                    "anchor": float(w_data * (1.0 - s)),
                    "n": int(bucket.sum()), "delay": delay,
                    "scale": float(s)})
            return
        entries = pipeline.train_groups(
            sim.params, sim.groups, sim._group_steps, train_mask, keys,
            epochs=cfg.local_epochs, batch_size=cfg.batch_size,
            lr=cfg.lr, prox_mu=cfg.prox_mu, return_entries=True)
        if entries is None:
            return
        merged, w, row_ids = entries
        land_rows = land[row_ids]            # padding rows keep weight 0
        for k in np.unique(land_rows[w > 0]):
            delay = max(0, self._tick_round(int(k)) - rnd)
            s = staleness_weight(lam, delay) if self.weighted else 1.0
            wk = np.where(land_rows == k, w, 0.0).astype(np.float32)
            live = float(wk.sum())
            self._pending.setdefault(int(k), []).append({
                "src": rnd, "merged": merged,
                "w": (wk * np.float32(s) if s != 1.0 else wk),
                "anchor": float(live * (1.0 - s)),
                "n": int((wk > 0).sum()), "delay": delay,
                "scale": float(s)})

    def _process_due_ticks(self, rnd: int) -> None:
        """Fire every aggregation tick due by the end of round ``rnd``
        (in tick order: each tick is its own FedAvg event over the
        updates landing there).  An empty or zero-weight tick leaves the
        global model untouched — the streaming no-op broadcast."""
        sim = self.sim
        stats = self._stats[rnd]
        for k in self._due_ticks(rnd):
            items = self._pending.pop(k)
            anchor = sum(it["anchor"] for it in items)
            if sim.client_mesh is not None:
                num = items[0]["num"]
                den = items[0]["den"]
                for it in items[1:]:
                    num = jax.tree.map(jnp.add, num, it["num"])
                    den = den + it["den"]
                if anchor > 0.0:             # staleness-discounted mass
                    a = jnp.float32(anchor)
                    num = jax.tree.map(
                        lambda nl, p: nl + a * p.astype(nl.dtype),
                        num, sim.params)
                    den = den + a
                # the summed partials are fresh/single-use: the donated
                # finisher is safe here
                sim.params = pipeline.aggregate_sharded(sim.params,
                                                        (num, den))
            else:
                w = np.concatenate([it["w"] for it in items])
                if float(w.sum()) + anchor <= 0.0:
                    continue                 # zero-weight tick: no-op
                merged = items[0]["merged"] if len(items) == 1 else \
                    jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                 *[it["merged"] for it in items])
                if anchor > 0.0:
                    merged = jax.tree.map(
                        lambda m, p: jnp.concatenate([m, p[None]]),
                        merged, sim.params)
                    w = np.append(w, np.float32(anchor))
                sim.params = _fedavg_pool(merged, jnp.asarray(w))
            for it in items:
                stats["n_agg"] += it["n"]
                if it["delay"] >= 1:
                    stats["n_stale"] += it["n"]
                stats["eff"] += it["n"] * it["scale"]
                stats["hist"][min(it["delay"], _HIST_BINS - 1)] += it["n"]

    # -- preemption safety (ISSUE 10) ----------------------------------
    def capture_state(self) -> Dict:
        """The wrapped simulation's state plus the streaming server's
        own: the pending landing-tick pools (device pytrees pulled to
        host) and the open per-round stat accumulators.  Together these
        make a mid-stream kill invisible — stragglers enqueued rounds
        ago land at the same tick with the same weights after resume."""
        pending = {}
        for k, items in self._pending.items():
            out = []
            for it in items:
                e: Dict = {}
                for name, v in it.items():
                    if name in ("merged", "num", "den"):
                        e[name] = jax.device_get(v)
                    elif name == "w":
                        e[name] = np.asarray(v, np.float32)
                    else:
                        e[name] = _ENTRY_SCALARS[name](v)
                out.append(e)
            pending[str(k)] = out
        stats = {str(r): {"n_agg": int(s["n_agg"]),
                          "n_stale": int(s["n_stale"]),
                          "eff": float(s["eff"]),
                          "hist": [int(h) for h in s["hist"]]}
                 for r, s in self._stats.items()}
        return {"sim": self.sim.capture_state(),
                "pending": pending, "stats": stats}

    def restore_state(self, state: Dict,
                      extra: Optional[Dict] = None) -> None:
        self.sim.restore_state(state["sim"], extra)
        self._pending = {}
        for k, items in state["pending"].items():
            out = []
            for it in items:
                e = {}
                for name, v in it.items():
                    if name in ("merged", "num", "den"):
                        e[name] = jax.tree.map(jnp.asarray, v)
                    elif name == "w":
                        e[name] = np.asarray(v, np.float32)
                    else:
                        e[name] = _ENTRY_SCALARS[name](v)
                out.append(e)
            self._pending[int(k)] = out
        self._stats = {int(r): {"n_agg": int(s["n_agg"]),
                                "n_stale": int(s["n_stale"]),
                                "eff": float(s["eff"]),
                                "hist": [int(h) for h in s["hist"]]}
                       for r, s in state["stats"].items()}

    # -- metrics rows ---------------------------------------------------
    def _round_row(self, rnd: int, host: Dict, acc_count: jax.Array,
                   n_test: int) -> Dict[str, float]:
        row = self.sim._round_row(rnd, host, acc_count, n_test)
        if self.sync_equivalent:
            return row
        st = self._stats.pop(rnd)
        row["n_aggregated"] = st["n_agg"]
        row["stale_frac"] = (st["n_stale"] / st["n_agg"]
                             if st["n_agg"] else 0.0)
        row["n_effective"] = st["eff"]
        row["rounds_behind_hist"] = "/".join(str(h) for h in st["hist"])
        return row

    def finish_round(self, rnd: int,
                     state: Dict[str, jax.Array]) -> Dict[str, float]:
        """Complete round ``rnd`` from a selection-prefix output (the
        sweep harness's per-seed entry point)."""
        host = self.sim.resolve_elect_overflow(rnd, jax.device_get(state))
        self._dispatch_training(rnd, host)
        acc, n_test = evaluate_accuracy_async(
            self.sim.params, self.sim.test_images, self.sim.test_labels,
            batch=256)
        return self._round_row(rnd, host, acc, n_test)

    # -- drivers ---------------------------------------------------------
    def run(self, n_rounds: Optional[int] = None,
            overlap: Optional[bool] = None, *,
            checkpointer=None,
            resume: Optional[bool] = None) -> List[Dict[str, float]]:
        """Drive ``n`` rounds.  Identical schedule to the sync drivers —
        serial or round-ahead — with the tick pool swapped in behind
        ``_dispatch_training``, so the prefix executables and dispatch
        order match the barrier drivers call for call.  Checkpoint /
        resume mirrors ``FLSimulation.run`` with the pending-tick queue
        riding along in every snapshot."""
        sim = self.sim
        n = n_rounds or sim.cfg.n_rounds
        ckpt = build_round_checkpointer(self.run_cfg, checkpointer)
        resume = self.run_cfg.resume if resume is None else resume
        rows, start = resume_rows(self, ckpt, resume)
        if overlap is None:
            overlap = self.run_cfg.overlap_rounds
        if not overlap:
            for r in range(start, n):
                rows.append(self.finish_round(r, sim.selection_state(r)))
                checkpoint_round(self, ckpt, r, rows)
            return rows
        if start >= n:
            return rows
        state = sim.selection_state(start)
        for r in range(start, n):
            host = jax.device_get(state)     # fence: the cohort gather
            host = sim.resolve_elect_overflow(r, host)
            self._dispatch_training(r, host)
            acc, n_test = evaluate_accuracy_async(
                sim.params, sim.test_images, sim.test_labels, batch=256)
            if r + 1 < n:                    # round-ahead: r+1's prefix
                state = sim.selection_state(r + 1)
            rows.append(self._round_row(r, host, acc, n_test))
            checkpoint_round(self, ckpt, r, rows)
        return rows
