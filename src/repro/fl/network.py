"""Cellular throughput model + TCP-Reno CWND-based throughput predictor
(paper §5.1, §6.1).

Physical model: base stations uniformly spaced along the road; a
vehicle's achievable rate interpolates between the worst MCS (0.24 Mbps,
cell edge) and the best (10.4 Mbps, under the BS) by distance, with
log-normal shadowing.  "MAX C/I" scheduling is approximated by letting
concurrent uploaders in a cell share the airtime proportionally to their
instantaneous rate.

Predictor: the participant-side estimate is an average of recent TCP Reno
congestion-window samples (paper: "averaging the CWND_SND values within a
certain period").  Reno AIMD is simulated against a loss probability that
rises toward the cell edge.  The paper only requires the predictor to be
*order-preserving* w.r.t. the real throughput — property-tested.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class NetworkConfig:
    road_length_m: float = 1000.0
    n_bs: int = 3
    best_rate_bps: float = 10.4e6
    worst_rate_bps: float = 0.24e6
    shadowing_sigma_db: float = 2.0
    packet_bytes: int = 1500
    rtt_s: float = 0.05                # vehicle<->BS loop for Reno dynamics
    cwnd_history: int = 16
    seed: int = 0


class CellularNetwork:
    def __init__(self, cfg: NetworkConfig):
        self.cfg = cfg
        self.bs_pos = (np.arange(cfg.n_bs) + 0.5) * (
            cfg.road_length_m / cfg.n_bs)
        self.rng = np.random.default_rng(cfg.seed + 53)

    # -- ground-truth physical rate ---------------------------------------
    def true_rate_bps(self, pos: np.ndarray,
                      rng: "np.random.Generator" = None) -> np.ndarray:
        rng = rng if rng is not None else self.rng
        d = np.min(np.abs(pos[:, None] - self.bs_pos[None, :]), axis=1)
        d_max = self.cfg.road_length_m / self.cfg.n_bs / 2.0
        frac = np.clip(1.0 - d / d_max, 0.0, 1.0)          # 1 under BS
        # log-scale interpolation between worst and best MCS
        log_rate = (np.log10(self.cfg.worst_rate_bps)
                    + frac * (np.log10(self.cfg.best_rate_bps)
                              - np.log10(self.cfg.worst_rate_bps)))
        shadow = rng.normal(0.0, self.cfg.shadowing_sigma_db / 10.0,
                            size=pos.shape)
        return 10.0 ** (log_rate + shadow)

    # -- TCP Reno CWND simulation ------------------------------------------
    def _loss_prob(self, rate_bps: np.ndarray) -> np.ndarray:
        # loss rises as the achievable rate falls (cell edge)
        frac = (np.log10(rate_bps) - np.log10(self.cfg.worst_rate_bps)) / (
            np.log10(self.cfg.best_rate_bps)
            - np.log10(self.cfg.worst_rate_bps))
        return np.clip(0.08 * (1.0 - frac) + 0.002, 0.002, 0.2)

    def cwnd_history(self, pos: np.ndarray, steps: int = 64,
                     rng: "np.random.Generator" = None) -> np.ndarray:
        """Simulate Reno for ``steps`` RTTs.  Returns (N, cwnd_history) of
        the most recent congestion-window samples (segments)."""
        rng = rng if rng is not None else self.rng
        n = pos.shape[0]
        rate = self.true_rate_bps(pos, rng=np.random.default_rng(0))
        p_loss = self._loss_prob(rate)
        bdp = rate * self.cfg.rtt_s / (8.0 * self.cfg.packet_bytes)
        cwnd = np.ones(n)
        hist = np.zeros((n, steps))
        for t in range(steps):
            loss = rng.random(n) < p_loss
            cwnd = np.where(loss, np.maximum(cwnd / 2.0, 1.0), cwnd + 1.0)
            cwnd = np.minimum(cwnd, np.maximum(bdp, 1.0))  # rate-limited
            hist[:, t] = cwnd
        return hist[:, -self.cfg.cwnd_history:]

    def predicted_throughput(self, pos: np.ndarray,
                             seed: int = None) -> np.ndarray:
        """CWND-average predictor (paper §5.1), in bps-equivalent units.
        ``seed`` pins the channel/loss realization (so the same physical
        round can be evaluated at two positions)."""
        rng = np.random.default_rng(seed) if seed is not None else None
        h = self.cwnd_history(pos, rng=rng)
        return h.mean(axis=1) * 8.0 * self.cfg.packet_bytes / self.cfg.rtt_s

    # -- upload time --------------------------------------------------------
    def upload_time_s(self, pos: np.ndarray, payload_bytes: float,
                      latency_s: float = 0.2) -> np.ndarray:
        return payload_bytes * 8.0 / self.true_rate_bps(pos) + latency_s
