"""Cellular throughput model + TCP-Reno CWND-based throughput predictor
(paper §5.1, §6.1).

Physical model: base stations uniformly spaced along the road; a
vehicle's achievable rate interpolates between the worst MCS (0.24 Mbps,
cell edge) and the best (10.4 Mbps, under the BS) by distance, with
log-normal shadowing.  "MAX C/I" scheduling is approximated by letting
concurrent uploaders in a cell share the airtime proportionally to their
instantaneous rate.

Predictor: the participant-side estimate is an average of recent TCP Reno
congestion-window samples (paper: "averaging the CWND_SND values within a
certain period").  Reno AIMD is simulated against a loss probability that
rises toward the cell edge.  The paper only requires the predictor to be
*order-preserving* w.r.t. the real throughput — property-tested.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class NetworkConfig:
    road_length_m: float = 1000.0
    n_bs: int = 3
    best_rate_bps: float = 10.4e6
    worst_rate_bps: float = 0.24e6
    shadowing_sigma_db: float = 2.0
    packet_bytes: int = 1500
    rtt_s: float = 0.05                # vehicle<->BS loop for Reno dynamics
    cwnd_history: int = 16
    seed: int = 0


class CellularNetwork:
    def __init__(self, cfg: NetworkConfig):
        self.cfg = cfg
        self.bs_pos = (np.arange(cfg.n_bs) + 0.5) * (
            cfg.road_length_m / cfg.n_bs)
        self.rng = np.random.default_rng(cfg.seed + 53)

    # -- ground-truth physical rate ---------------------------------------
    def true_rate_bps(self, pos: np.ndarray,
                      rng: "np.random.Generator" = None) -> np.ndarray:
        rng = rng if rng is not None else self.rng
        d = np.min(np.abs(pos[:, None] - self.bs_pos[None, :]), axis=1)
        d_max = self.cfg.road_length_m / self.cfg.n_bs / 2.0
        frac = np.clip(1.0 - d / d_max, 0.0, 1.0)          # 1 under BS
        # log-scale interpolation between worst and best MCS
        log_rate = (np.log10(self.cfg.worst_rate_bps)
                    + frac * (np.log10(self.cfg.best_rate_bps)
                              - np.log10(self.cfg.worst_rate_bps)))
        shadow = rng.normal(0.0, self.cfg.shadowing_sigma_db / 10.0,
                            size=pos.shape)
        return 10.0 ** (log_rate + shadow)

    # -- TCP Reno CWND simulation ------------------------------------------
    def _loss_prob(self, rate_bps: np.ndarray) -> np.ndarray:
        # loss rises as the achievable rate falls (cell edge)
        frac = (np.log10(rate_bps) - np.log10(self.cfg.worst_rate_bps)) / (
            np.log10(self.cfg.best_rate_bps)
            - np.log10(self.cfg.worst_rate_bps))
        return np.clip(0.08 * (1.0 - frac) + 0.002, 0.002, 0.2)

    def cwnd_history(self, pos: np.ndarray, steps: int = 64,
                     rng: "np.random.Generator" = None) -> np.ndarray:
        """Simulate Reno for ``steps`` RTTs.  Returns (N, cwnd_history) of
        the most recent congestion-window samples (segments)."""
        rng = rng if rng is not None else self.rng
        n = pos.shape[0]
        rate = self.true_rate_bps(pos, rng=np.random.default_rng(0))
        p_loss = self._loss_prob(rate)
        bdp = rate * self.cfg.rtt_s / (8.0 * self.cfg.packet_bytes)
        cwnd = np.ones(n)
        hist = np.zeros((n, steps))
        for t in range(steps):
            loss = rng.random(n) < p_loss
            cwnd = np.where(loss, np.maximum(cwnd / 2.0, 1.0), cwnd + 1.0)
            cwnd = np.minimum(cwnd, np.maximum(bdp, 1.0))  # rate-limited
            hist[:, t] = cwnd
        return hist[:, -self.cfg.cwnd_history:]

    def predicted_throughput(self, pos: np.ndarray,
                             seed: int = None) -> np.ndarray:
        """CWND-average predictor (paper §5.1), in bps-equivalent units.
        ``seed`` pins the channel/loss realization (so the same physical
        round can be evaluated at two positions)."""
        rng = np.random.default_rng(seed) if seed is not None else None
        h = self.cwnd_history(pos, rng=rng)
        return h.mean(axis=1) * 8.0 * self.cfg.packet_bytes / self.cfg.rtt_s

    # -- upload time --------------------------------------------------------
    def upload_time_s(self, pos: np.ndarray, payload_bytes: float,
                      latency_s: float = 0.2) -> np.ndarray:
        return payload_bytes * 8.0 / self.true_rate_bps(pos) + latency_s


# --------------------------------------------------------------------------
# jax-traceable twins (staged pipeline, fl/pipeline.py)
#
# Same math as CellularNetwork, but pure: the stateful numpy generator is
# replaced by explicit PRNG keys, so the selection prefix jits as one
# program and vmaps across seeds.  ``cfg`` is the frozen NetworkConfig —
# hashable, so callers can close over it or pass it through jit statics.
#
# Each ``*_jax`` function is split into a *field draw* (the PRNG
# realization over the full client axis) and a ``*_from_fields`` body
# that is purely elementwise in the client dimension.  The mesh-sharded
# selection prefix draws the fields globally (bit-identical to the
# single-device draw) and shards them alongside the other client-axis
# arrays, so the per-shard body needs no collective and no re-keying.
# --------------------------------------------------------------------------

# Reno is simulated for this many RTTs before the CWND window is read
# (matches CellularNetwork.cwnd_history's default).
_CWND_STEPS = 64

# the predictor evaluates the channel at a pinned shadowing realization
# (the host model's ``default_rng(0)``) so the same physical round can be
# queried at two positions; a constant key is the jax equivalent
_PINNED_CHANNEL_KEY = 0


def true_rate_bps_from_shadow(cfg: NetworkConfig, pos: jax.Array,
                              shadow: jax.Array) -> jax.Array:
    """Achievable rate at ``pos`` given a *raw standard-normal* shadowing
    field (one value per client) — elementwise in the client axis, so a
    shard of positions plus the matching shard of the field yields the
    same rates the full arrays would."""
    bs_pos = (jnp.arange(cfg.n_bs) + 0.5) * (cfg.road_length_m / cfg.n_bs)
    d = jnp.min(jnp.abs(pos[:, None] - bs_pos[None, :]), axis=1)
    d_max = cfg.road_length_m / cfg.n_bs / 2.0
    frac = jnp.clip(1.0 - d / d_max, 0.0, 1.0)             # 1 under BS
    log_rate = (np.log10(cfg.worst_rate_bps)
                + frac * (np.log10(cfg.best_rate_bps)
                          - np.log10(cfg.worst_rate_bps)))
    return 10.0 ** (log_rate + shadow * (cfg.shadowing_sigma_db / 10.0))


def true_rate_bps_jax(cfg: NetworkConfig, pos: jax.Array,
                      key: jax.Array) -> jax.Array:
    """Achievable rate at ``pos`` with log-normal shadowing drawn from
    ``key`` — the pure twin of ``CellularNetwork.true_rate_bps``."""
    return true_rate_bps_from_shadow(cfg, pos,
                                     jax.random.normal(key, pos.shape))


def _loss_prob_jax(cfg: NetworkConfig, rate_bps: jax.Array) -> jax.Array:
    frac = (jnp.log10(rate_bps) - np.log10(cfg.worst_rate_bps)) / (
        np.log10(cfg.best_rate_bps) - np.log10(cfg.worst_rate_bps))
    return jnp.clip(0.08 * (1.0 - frac) + 0.002, 0.002, 0.2)


def pinned_channel_shadow(n: int) -> jax.Array:
    """The predictor's pinned shadowing realization over ``n`` clients
    (the jax equivalent of the host model's ``default_rng(0)``)."""
    return jax.random.normal(jax.random.PRNGKey(_PINNED_CHANNEL_KEY), (n,))


def cwnd_loss_fields(key: jax.Array, n: int,
                     steps: int = _CWND_STEPS) -> jax.Array:
    """The Reno simulation's per-RTT loss draws as an explicit
    ``(steps, n)`` uniform field.  vmapping ``uniform`` over the split
    keys produces bit-identical values to drawing inside the scan, so
    the field-based history below matches the key-based one exactly."""
    return jax.vmap(lambda k: jax.random.uniform(k, (n,)))(
        jax.random.split(key, steps))


def cwnd_history_from_fields(cfg: NetworkConfig, pos: jax.Array,
                             shadow: jax.Array,
                             loss_u: jax.Array) -> jax.Array:
    """Reno AIMD over precomputed random fields -> (N, cwnd_history).
    ``shadow``: raw normal channel field; ``loss_u``: (steps, N) uniform
    loss draws.  Elementwise in the client axis."""
    rate = true_rate_bps_from_shadow(cfg, pos, shadow)
    p_loss = _loss_prob_jax(cfg, rate)
    bdp = rate * cfg.rtt_s / (8.0 * cfg.packet_bytes)

    def step(cwnd, u):
        loss = u < p_loss
        cwnd = jnp.where(loss, jnp.maximum(cwnd / 2.0, 1.0), cwnd + 1.0)
        cwnd = jnp.minimum(cwnd, jnp.maximum(bdp, 1.0))    # rate-limited
        return cwnd, cwnd

    _, hist = jax.lax.scan(step, jnp.ones(pos.shape), loss_u, unroll=8)
    return hist[-cfg.cwnd_history:].T


def cwnd_history_jax(cfg: NetworkConfig, pos: jax.Array, key: jax.Array,
                     steps: int = _CWND_STEPS) -> jax.Array:
    """Reno AIMD for ``steps`` RTTs -> (N, cwnd_history) recent windows."""
    return cwnd_history_from_fields(
        cfg, pos, pinned_channel_shadow(pos.shape[0]),
        cwnd_loss_fields(key, pos.shape[0], steps))


def predicted_throughput_from_fields(cfg: NetworkConfig, pos: jax.Array,
                                     shadow: jax.Array,
                                     loss_u: jax.Array) -> jax.Array:
    """CWND-average predictor over precomputed fields (sharded prefix)."""
    h = cwnd_history_from_fields(cfg, pos, shadow, loss_u)
    return h.mean(axis=1) * 8.0 * cfg.packet_bytes / cfg.rtt_s


def predicted_throughput_jax(cfg: NetworkConfig, pos: jax.Array,
                             key: jax.Array) -> jax.Array:
    """CWND-average predictor (paper §5.1) in bps-equivalent units."""
    h = cwnd_history_jax(cfg, pos, key)
    return h.mean(axis=1) * 8.0 * cfg.packet_bytes / cfg.rtt_s


def upload_time_s_from_shadow(cfg: NetworkConfig, pos: jax.Array,
                              payload_bytes: float, shadow: jax.Array,
                              latency_s: float = 0.2) -> jax.Array:
    return (payload_bytes * 8.0 / true_rate_bps_from_shadow(cfg, pos, shadow)
            + latency_s)


def upload_time_s_jax(cfg: NetworkConfig, pos: jax.Array,
                      payload_bytes: float, key: jax.Array,
                      latency_s: float = 0.2) -> jax.Array:
    return upload_time_s_from_shadow(cfg, pos, payload_bytes,
                                     jax.random.normal(key, pos.shape),
                                     latency_s)
