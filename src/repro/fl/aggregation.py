"""Model aggregation (paper Eq. 2) and FedProx local objective.

FedAvg: w_g = sum_i (|D_i|/|D|) w_i over the models that arrived before
the deadline.  FedProx (cited as [17]) adds mu/2 * ||w - w_g||^2 to the
local objective — implemented as a gradient term in the local trainer.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp

Params = Any


def fedavg(models: Sequence[Params], weights: Sequence[float]) -> Params:
    """Eq. 2: sample-quantity-weighted average of local models."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-9)

    def avg(*leaves):
        stacked = jnp.stack(leaves)
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree.map(avg, *models)


def fedavg_masked(stacked_models: Params, weights: jax.Array) -> Params:
    """FedAvg over a leading client axis with (possibly zero) weights —
    jit-friendly form used by the round engine.  weights: (C,)."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)

    def avg(leaf):
        return jnp.tensordot(w, leaf.astype(jnp.float32), axes=1).astype(
            leaf.dtype)

    return jax.tree.map(avg, stacked_models)


def global_loss(losses: jax.Array, weights: jax.Array) -> jax.Array:
    """Eq. 3: the sample-weighted global loss."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    return (losses * w).sum()


def prox_grad(params: Params, global_params: Params, mu: float) -> Params:
    """FedProx proximal gradient: mu * (w - w_g)."""
    return jax.tree.map(lambda p, g: mu * (p - g), params, global_params)
