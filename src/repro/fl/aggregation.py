"""Model aggregation (paper Eq. 2) and FedProx local objective.

FedAvg: w_g = sum_i (|D_i|/|D|) w_i over the models that arrived before
the deadline.  FedProx (cited as [17]) adds mu/2 * ||w - w_g||^2 to the
local objective — implemented as a gradient term in the local trainer.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any


def fedavg(models: Sequence[Params], weights: Sequence[float]) -> Params:
    """Eq. 2: sample-quantity-weighted average of local models."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.maximum(w.sum(), 1e-9)

    def avg(*leaves):
        stacked = jnp.stack(leaves)
        return jnp.tensordot(w, stacked, axes=1).astype(leaves[0].dtype)

    return jax.tree.map(avg, *models)


def fedavg_masked(stacked_models: Params, weights: jax.Array,
                  axis_name: Optional[str] = None) -> Params:
    """FedAvg over a leading client axis with (possibly zero) weights —
    jit-friendly form used by the round engine.  weights: (C,).

    ``axis_name`` is the mesh-sharded form: inside ``shard_map`` the
    leading axis holds only this device's shard of the cohort, so the
    weight total and the weighted model sum each finish with a ``psum``
    over the named mesh axis — the global average lands replicated on
    every device without the per-device stacks ever being gathered."""
    tot = weights.sum()
    if axis_name is not None:
        tot = jax.lax.psum(tot, axis_name)
    w = weights / jnp.maximum(tot, 1e-9)

    def avg(leaf):
        part = jnp.tensordot(w, leaf.astype(jnp.float32), axes=1)
        if axis_name is not None:
            part = jax.lax.psum(part, axis_name)
        return part.astype(leaf.dtype)

    return jax.tree.map(avg, stacked_models)


def fedavg_sums(stacked_models: Params, weights: jax.Array,
                axis_name: Optional[str] = None
                ) -> Tuple[Params, jax.Array]:
    """The *unnormalized* half of Eq. 2: ``(sum_i w_i * model_i, sum_i
    w_i)``, psum'd over ``axis_name`` when sharded.  The grouped trainer
    accumulates these partial sums across capacity groups (each group is
    one trainer dispatch) and divides once at the end, so a multi-group
    round still aggregates as a single global weighted average."""
    tot = weights.sum()
    if axis_name is not None:
        tot = jax.lax.psum(tot, axis_name)

    def wsum(leaf):
        part = jnp.tensordot(weights, leaf.astype(jnp.float32), axes=1)
        if axis_name is not None:
            part = jax.lax.psum(part, axis_name)
        return part

    return jax.tree.map(wsum, stacked_models), tot


def global_loss(losses: jax.Array, weights: jax.Array) -> jax.Array:
    """Eq. 3: the sample-weighted global loss."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    return (losses * w).sum()


def prox_grad(params: Params, global_params: Params, mu: float) -> Params:
    """FedProx proximal gradient: mu * (w - w_g)."""
    return jax.tree.map(lambda p, g: mu * (p - g), params, global_params)
