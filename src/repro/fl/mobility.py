"""Freeway mobility model (paper §6.1: 30 vehicles, 1000 m straight road,
freeway model).

Vehicles keep lane-constant speeds (freeway model: no lane change modelled,
speed jitter bounded) and wrap around the road segment, which keeps the
density stationary like SUMO's closed-loop freeway scenario.  Two initial
distributions reproduce Fig. 7: ``uniform`` and ``extreme`` (vehicles with
the best evaluations crowded into one small area, the rest in another).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# period parameter of the per-vehicle speed-jitter sinusoid: speed varies
# as jitter * sin(t / _JITTER_PERIOD_S + phase)
_JITTER_PERIOD_S = 7.0


@dataclass(frozen=True)
class MobilityConfig:
    n_vehicles: int = 30
    road_length_m: float = 1000.0
    v_min_mps: float = 20.0          # ~72 km/h
    v_max_mps: float = 33.0          # ~120 km/h
    speed_jitter: float = 1.0
    distribution: str = "uniform"    # uniform | extreme
    cluster_span_m: float = 150.0    # extreme: span of each crowd
    seed: int = 0


class FreewayMobility:
    def __init__(self, cfg: MobilityConfig,
                 quality_rank: Optional[np.ndarray] = None):
        """``quality_rank``: permutation of vehicles, best first — used by
        the 'extreme' distribution to crowd good vehicles together."""
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed + 31)
        n = cfg.n_vehicles
        self.speeds = rng.uniform(cfg.v_min_mps, cfg.v_max_mps, n)
        if cfg.distribution == "uniform":
            self.x0 = rng.uniform(0, cfg.road_length_m, n)
        elif cfg.distribution == "extreme":
            rank = (quality_rank if quality_rank is not None
                    else np.arange(n))
            half = n // 2
            x0 = np.empty(n)
            # best half crowded at one end, worst half at the other
            x0[rank[:half]] = rng.uniform(0, cfg.cluster_span_m, half)
            x0[rank[half:]] = rng.uniform(
                cfg.road_length_m - cfg.cluster_span_m,
                cfg.road_length_m, n - half)
            self.x0 = x0
        else:
            raise ValueError(cfg.distribution)
        jr = np.random.default_rng(cfg.seed + 37)
        self._jitter_phase = jr.uniform(0, 2 * np.pi, n)

    def displacement_m(self, t_s: float) -> np.ndarray:
        """Unwrapped displacement since t=0: the exact integral of the
        instantaneous speed ``speeds + jitter * sin(t/T + phase)`` over
        ``[0, t_s]``.  The jitter contribution is the integral of a
        sinusoid, so it stays bounded by ``2 * speed_jitter * T`` for all
        ``t_s`` instead of growing linearly in elapsed time."""
        amp, period = self.cfg.speed_jitter, _JITTER_PERIOD_S
        jitter_disp = amp * period * (
            np.cos(self._jitter_phase)
            - np.cos(t_s / period + self._jitter_phase))
        return self.speeds * t_s + jitter_disp

    def positions(self, t_s: float) -> np.ndarray:
        """Deterministic in ``t_s`` (speed jitter is a per-vehicle
        sinusoid integrated in closed form), so the same instant can be
        queried repeatedly — needed by the staleness experiment."""
        x = self.x0 + self.displacement_m(t_s)
        return np.mod(x, self.cfg.road_length_m)


def positions_jax(x0: jax.Array, speeds: jax.Array, jitter_phase: jax.Array,
                  t_s: jax.Array, *, road_length_m: float,
                  speed_jitter: float) -> jax.Array:
    """jax-traceable twin of ``FreewayMobility.positions``: same closed-
    form jitter integral over the model's constant arrays, usable inside
    the staged selection prefix (``fl/pipeline.py``) where ``t_s`` is a
    traced scalar.  ``t_s`` broadcasts, so a per-client completion-time
    vector queries each vehicle's position at its own upload instant."""
    jitter_disp = speed_jitter * _JITTER_PERIOD_S * (
        jnp.cos(jitter_phase)
        - jnp.cos(t_s / _JITTER_PERIOD_S + jitter_phase))
    return jnp.mod(x0 + speeds * t_s + jitter_disp, road_length_m)


def coverage_active(pos: jax.Array, *, road_length_m: float,
                    churn_rate: float) -> jax.Array:
    """Mobility-driven churn mask (event-driven fleet, ISSUE 6).

    The RSU's coverage window spans ``[0, (1 - churn_rate) * L)`` of the
    wrapped road: a vehicle whose position falls in the uncovered tail
    has *departed* (it neither probes nor gets selected, and an upload
    completing while uncovered is lost).  Because vehicles wrap around
    the closed road, the process continuously churns — each vehicle
    leaves and re-enters coverage once per lap — while the stationary
    active fraction stays ``1 - churn_rate``.  ``churn_rate=0`` is full
    coverage (every client active, the synchronous baseline) and
    ``churn_rate=1`` an empty fleet."""
    return pos < (1.0 - churn_rate) * road_length_m
