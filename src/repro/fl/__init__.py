from repro.fl.aggregation import fedavg, fedavg_masked, global_loss
from repro.fl.async_server import EventDrivenServer
from repro.fl.client import (dataset_loss, dataset_loss_batch,
                             dataset_loss_packed, evaluate_accuracy,
                             local_train, local_train_batch)
from repro.fl.mobility import (FreewayMobility, MobilityConfig,
                               coverage_active)
from repro.fl.network import CellularNetwork, NetworkConfig
from repro.fl.partition import (PartitionConfig, pad_clients, partition,
                                stack_clients)
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig, add_run_arguments, resolve_run
from repro.fl.schemes import get_scheme, register_scheme, scheme_names
from repro.fl.timing import (TimingConfig, completes_before_deadline,
                             staleness_weight, training_time_s)

__all__ = [
    "fedavg", "fedavg_masked", "global_loss", "EventDrivenServer",
    "dataset_loss", "dataset_loss_batch", "dataset_loss_packed",
    "evaluate_accuracy", "local_train",
    "local_train_batch", "FreewayMobility", "MobilityConfig",
    "coverage_active",
    "CellularNetwork", "NetworkConfig", "PartitionConfig", "pad_clients",
    "partition", "stack_clients", "FLSimConfig", "FLSimulation",
    "RunConfig", "add_run_arguments", "resolve_run",
    "get_scheme", "register_scheme", "scheme_names",
    "TimingConfig", "completes_before_deadline", "staleness_weight",
    "training_time_s",
]
