from repro.fl.aggregation import fedavg, fedavg_masked, global_loss
from repro.fl.client import (dataset_loss, dataset_loss_batch,
                             dataset_loss_packed, evaluate_accuracy,
                             local_train, local_train_batch)
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig
from repro.fl.partition import (PartitionConfig, pad_clients, partition,
                                stack_clients)
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.timing import TimingConfig, completes_before_deadline, \
    training_time_s

__all__ = [
    "fedavg", "fedavg_masked", "global_loss", "dataset_loss",
    "dataset_loss_batch", "dataset_loss_packed", "evaluate_accuracy",
    "local_train",
    "local_train_batch", "FreewayMobility", "MobilityConfig",
    "CellularNetwork", "NetworkConfig", "PartitionConfig", "pad_clients",
    "partition", "stack_clients", "FLSimConfig", "FLSimulation",
    "TimingConfig", "completes_before_deadline", "training_time_s",
]
