"""One ``RunConfig`` for every entry point (ISSUE 6 API consolidation).

Execution knobs used to sprawl across three layers: ``fused_probe``
lived on ``StageConfig`` *and* ``FLSimConfig`` *and* both launcher CLIs;
the engine, mesh spec and round-overlap flags were duplicated the same
way.  ``RunConfig`` is now the single owner of **how** a simulation
executes — engine, fused probe, round overlap, client-mesh spec, and the
event-driven server's churn/staleness/cadence axis — while
``FLSimConfig`` keeps owning **what** is simulated (schemes, data,
timing, network).  All three entry points construct from it:

    FLSimulation(cfg, run=RunConfig(...))
    repro.launch.fl_sim  --server event --churn-rate 0.3 ...
    repro.launch.sweep   --churn-rates 0,0.3 --staleness-lambdas 0,1 ...

The old ``FLSimConfig.engine/fused_probe/overlap_rounds`` constructor
kwargs keep working for one release: ``resolve_run`` folds them into the
``RunConfig`` behind a ``DeprecationWarning``.

Defaults flipped by ISSUE 6 (both parity-pinned since ISSUE 5):
``fused_probe=True`` (tight probe pack + fused probe->evaluate kernel)
and ``overlap_rounds=True`` (round-ahead scheduler).  The legacy
batch-aligned pack survives behind ``--compat-aligned-pack``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

ENGINES = ("batched", "loop")
SERVERS = ("sync", "event")
STALENESS_MODES = ("drop", "weighted")
ELECT_MODES = ("auto", "gather", "windowed")

# fleets at or above this size default to the windowed O(N/K * W)
# election under elect="auto"; smaller fleets keep the dense gather
# seam (the window covers most of the fleet anyway, so there is
# nothing to win)
AUTO_WINDOWED_MIN_CLIENTS = 512

# FLSimConfig fields that moved here; ``resolve_run`` folds non-None
# values into the RunConfig behind a DeprecationWarning
DEPRECATED_SIM_FIELDS = ("engine", "fused_probe", "overlap_rounds")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """How a simulation executes (vs ``FLSimConfig``: what it simulates).

    Async axis (any non-default value promotes ``server`` to "event"):

    - ``churn_rate``: fraction of the road outside RSU coverage; clients
      whose position falls past ``(1-rate)*road_length`` are departed
      for that round (no probe, no selection) and a client that leaves
      coverage before its upload completes loses that update.
    - ``staleness``: "drop" keeps the Eq. 6 hard deadline ({1 at
      deadline, 0 after}); "weighted" trains stragglers too and folds
      ``1/(1 + lambda * delay_rounds)`` into their FedAvg weight.
    - ``agg_cadence_s``: the server aggregates every ``T_agg`` simulated
      seconds instead of at the round barrier (None = round period)."""
    engine: str = "batched"              # batched (vmapped) | loop (ref)
    fused_probe: bool = True             # fused probe->evaluate + tight pack
    overlap_rounds: bool = True          # round-ahead scheduler
    mesh: Optional[str] = None           # "clients=K" client-mesh spec
    server: str = "sync"                 # sync | event
    churn_rate: float = 0.0              # 0 = full coverage, no churn
    staleness: str = "drop"              # drop | weighted
    staleness_lambda: float = 0.0        # weighted: 1/(1 + lambda * delay)
    agg_cadence_s: Optional[float] = None  # None = round period (deadline_s)
    # DCS election seam: auto (windowed for large fleets), gather (the
    # dense O(N^2) election on gathered (N,) vectors), windowed (the
    # O(N/K * W) position-sorted window; overflow rounds re-run through
    # gather, so masks stay bit-identical either way)
    elect: str = "auto"
    elect_window: int = 0                # sorted window per side (0 = auto)
    # Preemption safety (ISSUE 10): when ``checkpoint_dir`` is set the
    # drivers snapshot complete round state every ``checkpoint_every``
    # rounds (atomic + checksummed; repro.train.checkpoint) and
    # ``resume=True`` restores the latest good snapshot before running —
    # the resumed trajectory's rows, masks and params are pinned
    # bit-identical to an uninterrupted run.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1
    resume: bool = False

    def resolved(self) -> "RunConfig":
        """Validate and normalize: any async knob promotes ``server`` to
        "event" (churn and cadence semantics only exist there)."""
        if self.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}: "
                             f"{self.engine!r}")
        if self.server not in SERVERS:
            raise ValueError(f"server must be one of {SERVERS}: "
                             f"{self.server!r}")
        if self.staleness not in STALENESS_MODES:
            raise ValueError(f"staleness must be one of {STALENESS_MODES}: "
                             f"{self.staleness!r}")
        if not 0.0 <= self.churn_rate <= 1.0:
            raise ValueError(f"churn_rate must be in [0, 1]: "
                             f"{self.churn_rate}")
        if self.staleness_lambda < 0.0:
            raise ValueError(f"staleness_lambda must be >= 0: "
                             f"{self.staleness_lambda}")
        if self.agg_cadence_s is not None and self.agg_cadence_s <= 0.0:
            raise ValueError(f"agg_cadence_s must be > 0: "
                             f"{self.agg_cadence_s}")
        if self.elect not in ELECT_MODES:
            raise ValueError(f"elect must be one of {ELECT_MODES}: "
                             f"{self.elect!r}")
        if self.elect_window < 0:
            raise ValueError(f"elect_window must be >= 0: "
                             f"{self.elect_window}")
        if self.checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1: "
                             f"{self.checkpoint_every}")
        if self.resume and not self.checkpoint_dir:
            raise ValueError("resume=True requires checkpoint_dir")
        server = self.server
        if (self.churn_rate > 0.0 or self.staleness == "weighted"
                or self.agg_cadence_s is not None):
            server = "event"
        if server == "event" and self.staleness == "weighted" \
                and self.engine != "batched":
            raise ValueError("staleness='weighted' trains stragglers "
                             "through the batched engine; engine="
                             f"{self.engine!r} is not supported")
        if server != self.server:
            return dataclasses.replace(self, server=server)
        return self

    def to_stage_config(self, cfg, *, n_clients: int, probe_batch: int = 128):
        """Build the jit-static ``StageConfig`` from one ``FLSimConfig``
        plus this run's device-level knobs (fused probe, churn)."""
        from repro.fl.pipeline import StageConfig
        from repro.fl.timing import TimingConfig
        elect = self.elect
        if elect == "auto":
            elect = ("windowed" if n_clients >= AUTO_WINDOWED_MIN_CLIENTS
                     else "gather")
        return StageConfig(
            scheme=cfg.scheme, n_clients=n_clients,
            comm_range_m=cfg.comm_range_m, top_m=cfg.top_m,
            e_tau=cfg.e_tau, n_clients_central=cfg.n_clients_central,
            model_bytes=cfg.model_bytes,
            road_length_m=cfg.mobility.road_length_m,
            speed_jitter=cfg.mobility.speed_jitter,
            timing=TimingConfig(cfg.local_epochs, cfg.batch_size,
                                deadline_s=cfg.deadline_s),
            network=cfg.network, probe_batch=probe_batch,
            fused_probe=self.fused_probe,
            churn_rate=self.churn_rate,
            elect=elect, elect_window=self.elect_window)

    @classmethod
    def from_args(cls, args, base: Optional["RunConfig"] = None
                  ) -> "RunConfig":
        """Build from an argparse namespace (``add_run_arguments``).
        Absent attributes keep the ``base`` (default) values, so any CLI
        that exposes a subset of the flags still resolves."""
        run = base or cls()
        kw = {}
        fused = run.fused_probe or bool(getattr(args, "fused_probe", False))
        if getattr(args, "compat_aligned_pack", False):
            fused = False
        kw["fused_probe"] = fused
        overlap = run.overlap_rounds or bool(getattr(args, "overlap_rounds",
                                                     False))
        if getattr(args, "no_overlap_rounds", False):
            overlap = False
        kw["overlap_rounds"] = overlap
        for attr, field in (("engine", "engine"), ("mesh", "mesh"),
                            ("server", "server"),
                            ("staleness", "staleness"),
                            ("churn_rate", "churn_rate"),
                            ("staleness_lambda", "staleness_lambda"),
                            ("agg_cadence", "agg_cadence_s"),
                            ("elect", "elect"),
                            ("elect_window", "elect_window"),
                            ("checkpoint_dir", "checkpoint_dir"),
                            ("checkpoint_every", "checkpoint_every")):
            v = getattr(args, attr, None)
            if v is not None:
                kw[field] = v
        if getattr(args, "resume", False):
            kw["resume"] = True
        if kw.get("agg_cadence_s") == 0.0:       # CLI "0" = round period
            kw["agg_cadence_s"] = None
        return dataclasses.replace(run, **kw).resolved()


def add_run_arguments(ap) -> None:
    """Install the shared ``RunConfig`` flags on an argparse parser
    (consumed by ``RunConfig.from_args``)."""
    ap.add_argument("--mesh", default=None, metavar="clients=K",
                    help="partition the in-round client axis over K "
                         "devices (CPU: emulated host devices)")
    ap.add_argument("--fused-probe", action="store_true",
                    help="deprecated no-op: the fused probe->evaluate "
                         "fast path is the default now")
    ap.add_argument("--compat-aligned-pack", action="store_true",
                    help="legacy batch-aligned probe pack + unfused "
                         "staged probe (the pre-ISSUE-6 default)")
    ap.add_argument("--overlap-rounds", action="store_true",
                    help="deprecated no-op: the round-ahead scheduler "
                         "is the default now")
    ap.add_argument("--no-overlap-rounds", action="store_true",
                    help="serial round dispatch (disable the round-ahead "
                         "scheduler)")
    ap.add_argument("--server", choices=SERVERS, default=None,
                    help="sync round barrier (default) or the "
                         "event-driven streaming server")
    ap.add_argument("--churn-rate", type=float, default=None,
                    help="coverage-window churn rate in [0,1] "
                         "(implies --server event)")
    ap.add_argument("--staleness", choices=STALENESS_MODES, default=None,
                    help="straggler policy: drop (Eq. 6 hard deadline) "
                         "or weighted (1/(1+lambda*delay_rounds))")
    ap.add_argument("--staleness-lambda", type=float, default=None,
                    help="staleness decay lambda for --staleness weighted")
    ap.add_argument("--agg-cadence", type=float, default=None,
                    help="aggregation cadence T_agg in simulated seconds "
                         "(0 = the round period; implies --server event)")
    ap.add_argument("--elect", choices=ELECT_MODES, default=None,
                    help="DCS election seam: auto (windowed for large "
                         "fleets), gather (dense O(N^2) on gathered "
                         "vectors), windowed (O(N/K*W) sorted window; "
                         "bit-identical masks via overflow fallback)")
    ap.add_argument("--elect-window", type=int, default=None,
                    help="windowed election: sorted neighbours per side "
                         "(0 = auto-size from fleet density)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for atomic per-round state snapshots "
                         "(enables preemption-safe runs)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="snapshot cadence in rounds (default 1)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest good checkpoint from "
                         "--checkpoint-dir before running (bit-identical "
                         "continuation; no-op when none exists)")


def resolve_run(sim_cfg, run: Optional[RunConfig] = None) -> RunConfig:
    """Resolve the effective ``RunConfig`` for a simulation, folding in
    the deprecated ``FLSimConfig`` execution kwargs (one-release
    compatibility shim)."""
    run = run if run is not None else RunConfig()
    overrides = {}
    for name in DEPRECATED_SIM_FIELDS:
        v = getattr(sim_cfg, name, None)
        if v is not None:
            warnings.warn(
                f"FLSimConfig.{name} is deprecated; pass "
                f"RunConfig({name}={v!r}) to FLSimulation(..., run=...) "
                f"instead", DeprecationWarning, stacklevel=3)
            overrides[name] = v
    if overrides:
        run = dataclasses.replace(run, **overrides)
    return run.resolved()
