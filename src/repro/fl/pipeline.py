"""Pure staged round pipeline (paper Alg. 1 steps 1-7 as data flow).

``FLSimulation.run_round`` used to be host-driven: mobility, features,
fuzzy evaluation, selection and the Eq. 6 deadline mask each round-trip
through numpy, so nothing above the per-group trainer could be vmapped
over seeds or sharded over devices.  This module splits the round into
**pure stage functions** with explicit state-in/state-out signatures:

    positions(statics, cfg, t)                    -> (N,) road positions
    features(statics, cfg, params, t, net_key)    -> (pos, raw (N, 4))
    evaluate(statics, feats_raw)                  -> (N,) fuzzy evals
    select(cfg, pos, evals, sel_key)              -> (N,) int32 mask
    deadline_filter(statics, cfg, pos, mask, key) -> (survivors, n_straggler)
    train_groups(...) / aggregate(...)            -> new global params

The probe -> evaluate -> select -> deadline prefix is jax-traceable end
to end and compiles as ONE jitted function (``selection_prefix``) with
no host round-trips; survivor indices cross to the host exactly once, at
the cohort gather in ``train_groups``.  ``selection_prefix_seeds`` vmaps
the same prefix across a stacked seed axis — the multi-seed sweep
harness (``repro.launch.sweep``) evaluates S seeds' selection stages in
a single dispatch.

Pipeline state is split by trace role:

- ``RoundStatics``: a pytree of arrays that never change across rounds
  (mobility constants, slowdowns, the packed Eq. 7 probe tensors, the
  fuzzy membership parameters).  Leaves, so a leading seed axis can be
  stacked on for ``vmap``.
- ``StageConfig``: a frozen (hashable) dataclass of scalars — scheme,
  selection/timing/network parameters — passed as a jit-static.
- per-round inputs: the round index and base PRNG keys (folded per
  round *inside* the trace, so the prefix is deterministic in
  ``(statics, params, rnd, keys)`` and re-runnable for any round).

Randomness: the stateful numpy generators of ``CellularNetwork`` are
replaced by explicit jax keys — the Reno CWND predictor and the upload
shadowing each draw from ``fold_in(net_key, rnd)``, and the predictor's
pinned channel realization (``default_rng(0)`` in the host model) maps
to a constant key.  Eq. 8 normalization happens inside the fuzzy kernel
(``kops.fuzzy_eval(..., normalize=True)``), so ``features`` emits *raw*
columns [|D_i|, TA bps, 1/C_i, LF].
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rules import build_rule_table
from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select, selection_stats)
from repro.fl.aggregation import fedavg_masked
from repro.fl.client import dataset_loss_packed, local_train_batch
from repro.fl.mobility import positions_jax
from repro.fl.network import (NetworkConfig, predicted_throughput_jax,
                              upload_time_s_jax)
from repro.fl.partition import ClientGroup
from repro.fl.timing import (TimingConfig, completes_before_deadline,
                             training_time_s)
from repro.kernels import ops as kops

Params = Any


# --------------------------------------------------------------------------
# pipeline state
# --------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("x0", "speeds", "jitter_phase", "slowdown", "n_valid",
                 "probe_images", "probe_labels", "probe_seg", "probe_counts",
                 "means", "sigmas", "level_centers"),
    meta_fields=())
@dataclasses.dataclass(frozen=True)
class RoundStatics:
    """Arrays that never change across rounds — the pure stages' closed-
    over world state, kept explicit so it can be stacked and vmapped."""
    # freeway mobility constants (fl/mobility.py)
    x0: jax.Array                 # (N,)
    speeds: jax.Array             # (N,)
    jitter_phase: jax.Array       # (N,)
    # per-client heterogeneity
    slowdown: jax.Array           # (N,) C_i >= 1
    n_valid: jax.Array            # (N,) float32 |D_i|
    # packed Eq. 7 probe (every client's valid probe samples, flat)
    probe_images: jax.Array       # (S, 28, 28, 1)
    probe_labels: jax.Array       # (S,)
    probe_seg: jax.Array          # (S,) client id per sample (N = padding)
    probe_counts: jax.Array       # (N,) samples per client
    # fuzzy evaluator membership parameters (core/fuzzy.py)
    means: jax.Array              # (4, 3)
    sigmas: jax.Array             # (4, 3)
    level_centers: jax.Array      # (9,)


@dataclasses.dataclass(frozen=True)
class StageConfig:
    """Hashable scalar configuration — one jit-static for the prefix."""
    scheme: str                   # dcs | ccs-fuzzy | random
    n_clients: int
    comm_range_m: float
    top_m: int
    e_tau: float
    n_clients_central: int
    model_bytes: float
    road_length_m: float
    speed_jitter: float
    timing: TimingConfig          # frozen: epochs/batch/B_exe/deadline
    network: NetworkConfig        # frozen: rates/shadowing/Reno params
    probe_batch: int = 128


@functools.lru_cache(maxsize=None)
def _rules() -> Tuple[np.ndarray, np.ndarray]:
    """The 81-rule base as host constants (static for the Pallas path)."""
    return build_rule_table()


# --------------------------------------------------------------------------
# stages (pure: explicit state in, arrays out)
# --------------------------------------------------------------------------

def positions(st: RoundStatics, cfg: StageConfig, t_s: jax.Array) -> jax.Array:
    """Mobility stage: wrapped freeway positions at time ``t_s``."""
    return positions_jax(st.x0, st.speeds, st.jitter_phase, t_s,
                         road_length_m=cfg.road_length_m,
                         speed_jitter=cfg.speed_jitter)


def features(st: RoundStatics, cfg: StageConfig, params: Params,
             t_s: jax.Array, net_key: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Probe stage (Alg. 1 steps 1-2): raw multi-objective features.

    Returns ``(pos (N,), feats (N, 4))`` with *raw* columns
    [SQ=|D_i|, TA=predicted bps, CC=1/C_i, LF=Eq. 7 loss] — Eq. 8
    per-column max-scaling is folded into the ``evaluate`` stage's
    kernel, so no normalization happens here."""
    pos = positions(st, cfg, t_s)
    sq_raw = st.n_valid
    ta_raw = predicted_throughput_jax(cfg.network, pos, net_key)
    cc_raw = 1.0 / st.slowdown
    lf_raw = dataset_loss_packed(params, st.probe_images, st.probe_labels,
                                 st.probe_seg, st.probe_counts,
                                 n_clients=cfg.n_clients,
                                 batch=cfg.probe_batch)
    feats = jnp.stack([sq_raw, ta_raw, cc_raw, lf_raw],
                      axis=1).astype(jnp.float32)
    return pos, feats


def evaluate(st: RoundStatics, feats_raw: jax.Array) -> jax.Array:
    """Fuzzy evaluation stage (paper §5): raw (N, 4) -> (N,) on [0, 100].
    Eq. 8 normalization runs inside the kernel (``normalize=True``)."""
    table, levels = _rules()
    return kops.fuzzy_eval(feats_raw, st.means, st.sigmas, table, levels,
                           st.level_centers, normalize=True)


def select(cfg: StageConfig, pos: jax.Array, evals: jax.Array,
           sel_key: jax.Array) -> jax.Array:
    """Selection stage (Alg. 1 step 4) -> int32 mask (N,)."""
    if cfg.scheme == "dcs":
        return dcs_select(pos, evals, comm_range=cfg.comm_range_m,
                          top_m=cfg.top_m, e_tau=cfg.e_tau)
    if cfg.scheme == "ccs-fuzzy":
        return ccs_fuzzy_select(evals, cfg.n_clients_central)
    if cfg.scheme == "random":
        return ccs_random_select(sel_key, cfg.n_clients,
                                 cfg.n_clients_central)
    raise ValueError(cfg.scheme)


def deadline_filter(st: RoundStatics, cfg: StageConfig, pos: jax.Array,
                    mask: jax.Array, upload_key: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Eq. 6 straggler stage: ``(survivors (N,) bool, n_straggler)``."""
    train_t = training_time_s(cfg.timing, st.slowdown, st.n_valid)
    upload_t = upload_time_s_jax(cfg.network, pos, cfg.model_bytes,
                                 upload_key)
    ok = completes_before_deadline(cfg.timing, train_t, upload_t)
    selected = mask > 0
    return selected & ok, (selected & ~ok).sum()


def _prefix(st: RoundStatics, params: Params, rnd: jax.Array,
            sel_key: jax.Array, net_key: jax.Array, *,
            cfg: StageConfig) -> Dict[str, jax.Array]:
    """Unjitted prefix body (also the vmap target)."""
    t_s = rnd.astype(jnp.float32) * cfg.timing.deadline_s
    k_sel = jax.random.fold_in(sel_key, rnd)
    k_pred, k_upload = jax.random.split(jax.random.fold_in(net_key, rnd))
    pos, feats = features(st, cfg, params, t_s, k_pred)
    evals = evaluate(st, feats)
    mask = select(cfg, pos, evals, k_sel)
    survivors, n_straggler = deadline_filter(st, cfg, pos, mask, k_upload)
    stats = selection_stats(mask, evals)
    return {"pos": pos, "feats": feats, "evals": evals, "mask": mask,
            "survivors": survivors, "n_straggler": n_straggler,
            "n_selected": stats["n_selected"],
            "n_survivor": survivors.sum(),
            "mean_eval_selected": stats["mean_eval_selected"]}


@functools.partial(jax.jit, static_argnames=("cfg",))
def selection_prefix(st: RoundStatics, params: Params, rnd: jax.Array,
                     sel_key: jax.Array, net_key: jax.Array, *,
                     cfg: StageConfig) -> Dict[str, jax.Array]:
    """The probe -> evaluate -> select -> deadline prefix as ONE compiled
    function: no host round-trips between stages.  ``rnd`` is a traced
    int32 scalar, so every round shares a single executable."""
    return _prefix(st, params, rnd, sel_key, net_key, cfg=cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def selection_prefix_seeds(st: RoundStatics, params: Params,
                           rnd: jax.Array, sel_keys: jax.Array,
                           net_keys: jax.Array, *,
                           cfg: StageConfig) -> Dict[str, jax.Array]:
    """The prefix vmapped across a leading seed axis.

    ``st``/``params`` carry stacked ``(S, ...)`` leaves (one slice per
    seed — same shapes, different data/partitions), ``sel_keys``/
    ``net_keys`` are ``(S,)``-leading key arrays.  One dispatch evaluates
    all S seeds' selection stages for round ``rnd``."""
    return jax.vmap(
        lambda s, p, ks, kn: _prefix(s, p, rnd, ks, kn, cfg=cfg)
    )(st, params, sel_keys, net_keys)


def stack_statics(statics: Sequence[RoundStatics]) -> RoundStatics:
    """Stack per-seed statics into one (S, ...)-leading pytree for
    ``selection_prefix_seeds`` (shapes must match across seeds — they do
    whenever the seeds share a partition profile)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *statics)


# --------------------------------------------------------------------------
# training stages (host gather -> device train -> aggregate)
# --------------------------------------------------------------------------

def cohort_bucket(k: int) -> int:
    """Cohort tensor size for k survivors: next multiple of 2, min 2 —
    jit compiles a handful of shapes no matter how the per-round
    selection count fluctuates.  The floor matters for capacity groups:
    a Table-3 big-group cohort of 1-2 must not train (and compile) 4
    padded 4500-sample slots."""
    return max(2, k + (k % 2))


def train_groups(params: Params, groups: Sequence[ClientGroup],
                 group_steps: Sequence[int], survivors: np.ndarray,
                 keys: jax.Array, *, epochs: int, batch_size: int,
                 lr: float, prox_mu: float
                 ) -> Optional[Tuple[Params, jax.Array]]:
    """Local-training stage (Eq. 1): one ``vmap(local_train)`` per
    capacity group over that group's surviving cohort.

    ``survivors`` is the single host-side crossing of the round — the
    cohort gather needs concrete indices to slice fixed-shape stacks.
    Returns ``(stacked models, weights)`` with padding duplicates at
    weight zero, or ``None`` for an empty round (no-op broadcast).
    Groups with an empty cohort are skipped — never padded from a
    nonexistent ``cohort[0]``."""
    if not survivors.any():
        return None
    stacks, weights = [], []
    for gi, g in enumerate(groups):
        cohort = np.where(survivors[g.client_ids])[0]       # group-local
        k = len(cohort)
        if k == 0:
            continue                         # empty cohort: skip group
        bucket = cohort_bucket(k)
        idx = np.concatenate([cohort, np.full(bucket - k, cohort[0])])
        stacked, _ = local_train_batch(
            params, jnp.asarray(g.images[idx]), jnp.asarray(g.labels[idx]),
            jnp.asarray(g.n_valid[idx]),
            keys[jnp.asarray(g.client_ids[idx])],
            epochs=epochs, batch_size=batch_size,
            steps_per_epoch=group_steps[gi], lr=lr, prox_mu=prox_mu)
        w = g.n_valid[idx].astype(np.float32)
        w[k:] = 0.0                          # padding duplicates drop out
        stacks.append(stacked)
        weights.append(w)
    merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *stacks)
    return merged, jnp.asarray(np.concatenate(weights))


def aggregate(params: Params,
              trained: Optional[Tuple[Params, jax.Array]]) -> Params:
    """FedAvg stage (Eq. 2) over the survivors; an empty round returns
    the global model unchanged (no-op broadcast)."""
    if trained is None:
        return params
    merged, weights = trained
    return fedavg_masked(merged, weights)
