"""Pure staged round pipeline (paper Alg. 1 steps 1-7 as data flow).

``FLSimulation.run_round`` used to be host-driven: mobility, features,
fuzzy evaluation, selection and the Eq. 6 deadline mask each round-trip
through numpy, so nothing above the per-group trainer could be vmapped
over seeds or sharded over devices.  This module splits the round into
**pure stage functions** with explicit state-in/state-out signatures:

    positions(statics, cfg, t)                    -> (N,) road positions
    features(statics, cfg, params, t, net_key)    -> (pos, raw (N, 4))
    evaluate(statics, feats_raw)                  -> (N,) fuzzy evals
    select(cfg, pos, evals, sel_key)              -> (N,) int32 mask
    deadline_filter(statics, cfg, pos, mask, key) -> (survivors, n_straggler)
    train_groups(...) / aggregate(...)            -> new global params

The probe -> evaluate -> select -> deadline prefix is jax-traceable end
to end and compiles as ONE jitted function (``selection_prefix``) with
no host round-trips; survivor indices cross to the host exactly once, at
the cohort gather in ``train_groups``.  ``selection_prefix_seeds`` vmaps
the same prefix across a stacked seed axis — the multi-seed sweep
harness (``repro.launch.sweep``) evaluates S seeds' selection stages in
a single dispatch.

Pipeline state is split by trace role:

- ``RoundStatics``: a pytree of arrays that never change across rounds
  (mobility constants, slowdowns, the packed Eq. 7 probe tensors, the
  fuzzy membership parameters).  Leaves, so a leading seed axis can be
  stacked on for ``vmap``.
- ``StageConfig``: a frozen (hashable) dataclass of scalars — scheme,
  selection/timing/network parameters — passed as a jit-static.
- per-round inputs: the round index and base PRNG keys (folded per
  round *inside* the trace, so the prefix is deterministic in
  ``(statics, params, rnd, keys)`` and re-runnable for any round).

Randomness: the stateful numpy generators of ``CellularNetwork`` are
replaced by explicit jax keys — the Reno CWND predictor and the upload
shadowing each draw from ``fold_in(net_key, rnd)``, and the predictor's
pinned channel realization (``default_rng(0)`` in the host model) maps
to a constant key.  Eq. 8 normalization happens inside the fuzzy kernel
(``kops.fuzzy_eval(..., normalize=True)``), so ``features`` emits *raw*
columns [|D_i|, TA bps, 1/C_i, LF].
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.rules import build_rule_table
from repro.core.selection import selection_stats
from repro.fl.aggregation import fedavg_masked, fedavg_sums
from repro.fl.client import (dataset_loss_packed, local_train_batch,
                             local_train_batch_donated)
from repro.fl.mobility import coverage_active, positions_jax
from repro.fl.schemes import ShardCtx, get_scheme
from repro.fl.network import (NetworkConfig, cwnd_loss_fields,
                              pinned_channel_shadow,
                              predicted_throughput_from_fields,
                              predicted_throughput_jax,
                              upload_time_s_from_shadow, upload_time_s_jax)
from repro.fl.partition import ClientGroup
from repro.fl.timing import (TimingConfig, completes_before_deadline,
                             training_time_s)
from repro.kernels import ops as kops
from repro.sharding.api import (CLIENT_AXIS, current_mesh, mesh_axis_size,
                                mesh_is_multihost, resolve_pspec)

Params = Any


# --------------------------------------------------------------------------
# pipeline state
# --------------------------------------------------------------------------

@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("x0", "speeds", "jitter_phase", "slowdown", "n_valid",
                 "probe_images", "probe_labels", "probe_seg", "probe_counts",
                 "means", "sigmas", "level_centers"),
    meta_fields=())
@dataclasses.dataclass(frozen=True)
class RoundStatics:
    """Arrays that never change across rounds — the pure stages' closed-
    over world state, kept explicit so it can be stacked and vmapped."""
    # freeway mobility constants (fl/mobility.py)
    x0: jax.Array                 # (N,)
    speeds: jax.Array             # (N,)
    jitter_phase: jax.Array       # (N,)
    # per-client heterogeneity
    slowdown: jax.Array           # (N,) C_i >= 1
    n_valid: jax.Array            # (N,) float32 |D_i|
    # packed Eq. 7 probe (every client's valid probe samples, flat)
    probe_images: jax.Array       # (S, 28, 28, 1)
    probe_labels: jax.Array       # (S,)
    probe_seg: jax.Array          # (S,) client id per sample (N = padding)
    probe_counts: jax.Array       # (N,) samples per client
    # fuzzy evaluator membership parameters (core/fuzzy.py)
    means: jax.Array              # (4, 3)
    sigmas: jax.Array             # (4, 3)
    level_centers: jax.Array      # (9,)


@dataclasses.dataclass(frozen=True)
class StageConfig:
    """Hashable scalar configuration — one jit-static for the prefix."""
    scheme: str                   # dcs | ccs-fuzzy | random
    n_clients: int
    comm_range_m: float
    top_m: int
    e_tau: float
    n_clients_central: int
    model_bytes: float
    road_length_m: float
    speed_jitter: float
    timing: TimingConfig          # frozen: epochs/batch/B_exe/deadline
    network: NetworkConfig        # frozen: rates/shadowing/Reno params
    probe_batch: int = 128
    # device-resident fused probe->evaluate fast path (kops.probe_fuzzy):
    # default OFF — the staged jnp path below stays the bitwise-pinned
    # reference.  ON, the Eq. 7 probe forward, Eq. 8 normalization and
    # Mamdani inference run as one fused op (one Pallas launch on TPU),
    # and the simulation packs the probe TIGHT (no per-client batch
    # alignment), so small clients stop paying dead probe rows.  Masks
    # are pinned bit-identical to the unfused path in
    # tests/test_probe_fuzzy.py; per-client losses may differ in the
    # last ulp (different — tighter — sample grouping).
    fused_probe: bool = False
    # coverage-window churn rate (event-driven fleet, ISSUE 6): clients
    # past (1-rate)*road_length are departed this round.  0.0 compiles
    # the exact churn-free graph — the gating is a static branch, so the
    # event server's sync-parity pin rests on an identical executable.
    churn_rate: float = 0.0
    # DCS election seam (ISSUE 9): "gather" keeps the dense O(N^2)
    # election (on all_gather'ed (N,) vectors in the sharded prefix);
    # "windowed" runs the O(N/K * W) position-sorted window — the
    # single-device sorted sweep, or the segment-bucketed ppermute halo
    # ring inside the shard_map.  Windowed rounds carry a runtime
    # ``elect_overflow`` flag; non-zero means a fixed window/buffer could
    # not hold every dense comparison and the round driver re-runs that
    # round with elect="gather" — so windowed masks are bit-identical to
    # the gather election whenever they are consumed.
    elect: str = "gather"
    elect_window: int = 0         # sorted neighbours per side (0 = auto)
    elect_capacity: int = 0       # shard->segment bucket slots (0 = auto)


@functools.lru_cache(maxsize=None)
def _rules() -> Tuple[np.ndarray, np.ndarray]:
    """The 81-rule base as host constants (static for the Pallas path)."""
    return build_rule_table()


# --------------------------------------------------------------------------
# stages (pure: explicit state in, arrays out)
# --------------------------------------------------------------------------

def positions(st: RoundStatics, cfg: StageConfig, t_s: jax.Array) -> jax.Array:
    """Mobility stage: wrapped freeway positions at time ``t_s``."""
    return positions_jax(st.x0, st.speeds, st.jitter_phase, t_s,
                         road_length_m=cfg.road_length_m,
                         speed_jitter=cfg.speed_jitter)


def features(st: RoundStatics, cfg: StageConfig, params: Params,
             t_s: jax.Array, net_key: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Probe stage (Alg. 1 steps 1-2): raw multi-objective features.

    Returns ``(pos (N,), feats (N, 4))`` with *raw* columns
    [SQ=|D_i|, TA=predicted bps, CC=1/C_i, LF=Eq. 7 loss] — Eq. 8
    per-column max-scaling is folded into the ``evaluate`` stage's
    kernel, so no normalization happens here."""
    pos = positions(st, cfg, t_s)
    sq_raw = st.n_valid
    ta_raw = predicted_throughput_jax(cfg.network, pos, net_key)
    cc_raw = 1.0 / st.slowdown
    lf_raw = dataset_loss_packed(params, st.probe_images, st.probe_labels,
                                 st.probe_seg, st.probe_counts,
                                 n_clients=cfg.n_clients,
                                 batch=cfg.probe_batch)
    feats = jnp.stack([sq_raw, ta_raw, cc_raw, lf_raw],
                      axis=1).astype(jnp.float32)
    return pos, feats


def evaluate(st: RoundStatics, feats_raw: jax.Array) -> jax.Array:
    """Fuzzy evaluation stage (paper §5): raw (N, 4) -> (N,) on [0, 100].
    Eq. 8 normalization runs inside the kernel (``normalize=True``)."""
    table, levels = _rules()
    return kops.fuzzy_eval(feats_raw, st.means, st.sigmas, table, levels,
                           st.level_centers, normalize=True)


def select(cfg: StageConfig, pos: jax.Array, evals: jax.Array,
           sel_key: jax.Array) -> jax.Array:
    """Selection stage (Alg. 1 step 4) -> int32 mask (N,).  Dispatches
    through the scheme registry (``fl/schemes.py``) — unknown names
    raise at trace time with the registered list."""
    return get_scheme(cfg.scheme).select(cfg, pos, evals, sel_key)


def deadline_filter(st: RoundStatics, cfg: StageConfig, pos: jax.Array,
                    mask: jax.Array, upload_key: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Eq. 6 straggler stage: ``(survivors (N,) bool, n_straggler)``."""
    train_t = training_time_s(cfg.timing, st.slowdown, st.n_valid)
    upload_t = upload_time_s_jax(cfg.network, pos, cfg.model_bytes,
                                 upload_key)
    ok = completes_before_deadline(cfg.timing, train_t, upload_t)
    selected = mask > 0
    return selected & ok, (selected & ~ok).sum()


def completion_time_s(st: RoundStatics, cfg: StageConfig, pos: jax.Array,
                      upload_key: jax.Array, t_s: jax.Array) -> jax.Array:
    """Absolute per-client upload-completion instants (N,) — the event-
    driven server's landing-tick input.  Draws the same shadow as
    ``deadline_filter`` from the same key (XLA CSEs the duplicate inside
    the jitted prefix), so ``t_done <= t_s + deadline`` iff the client
    survives Eq. 6."""
    train_t = training_time_s(cfg.timing, st.slowdown, st.n_valid)
    upload_t = upload_time_s_jax(cfg.network, pos, cfg.model_bytes,
                                 upload_key)
    return t_s + train_t + upload_t


def _prefix(st: RoundStatics, params: Params, rnd: jax.Array,
            sel_key: jax.Array, net_key: jax.Array, *,
            cfg: StageConfig) -> Dict[str, jax.Array]:
    """Unjitted prefix body (also the vmap target)."""
    t_s = rnd.astype(jnp.float32) * cfg.timing.deadline_s
    k_sel = jax.random.fold_in(sel_key, rnd)
    k_pred, k_upload = jax.random.split(jax.random.fold_in(net_key, rnd))
    if cfg.fused_probe:
        # fused fast path: probe forward + Eq. 8 + Mamdani as one op —
        # a single kernel launch on the Pallas impl, one fused XLA
        # subgraph on the jnp impl (plus the tight probe pack built by
        # FLSimulation when the flag is on)
        pos = positions(st, cfg, t_s)
        ta_raw = predicted_throughput_jax(cfg.network, pos, k_pred)
        aux = jnp.stack([st.n_valid, ta_raw, 1.0 / st.slowdown],
                        axis=1).astype(jnp.float32)
        table, levels = _rules()
        feats, evals = kops.probe_fuzzy(
            params, st.probe_images, st.probe_labels, st.probe_seg,
            st.probe_counts, aux, st.means, st.sigmas, table, levels,
            st.level_centers, n_clients=cfg.n_clients,
            batch=cfg.probe_batch)
    else:
        pos, feats = features(st, cfg, params, t_s, k_pred)
        evals = evaluate(st, feats)
    # churn stage (event-driven fleet): departed clients neither report
    # evaluations nor get selected.  Statically gated — churn_rate == 0
    # compiles the exact pre-churn graph, which the event server's
    # sync-parity pin (tests/test_async.py) rests on.
    if cfg.churn_rate > 0.0:
        active = coverage_active(pos, road_length_m=cfg.road_length_m,
                                 churn_rate=cfg.churn_rate)
        evals = jnp.where(active, evals, 0.0)
    scheme = get_scheme(cfg.scheme)
    windowed = None
    if cfg.elect == "windowed" and scheme.select_windowed is not None:
        windowed = scheme.select_windowed(cfg, pos, evals, k_sel)
    if windowed is not None:
        mask, elect_overflow = windowed
    else:
        mask = select(cfg, pos, evals, k_sel)
        elect_overflow = jnp.int32(0)
    if cfg.churn_rate > 0.0:
        mask = jnp.where(active, mask, 0)
    survivors, n_straggler = deadline_filter(st, cfg, pos, mask, k_upload)
    # event-server inputs: absolute completion instants + presence at
    # upload time (a client leaving coverage mid-training/upload loses
    # its pending update)
    t_done = completion_time_s(st, cfg, pos, k_upload, t_s)
    if cfg.churn_rate > 0.0:
        pos_done = positions_jax(st.x0, st.speeds, st.jitter_phase, t_done,
                                 road_length_m=cfg.road_length_m,
                                 speed_jitter=cfg.speed_jitter)
        alive_at_done = coverage_active(pos_done,
                                        road_length_m=cfg.road_length_m,
                                        churn_rate=cfg.churn_rate)
        n_active = active.sum()
    else:
        alive_at_done = jnp.ones_like(survivors)
        n_active = jnp.asarray(cfg.n_clients, jnp.int32)
    stats = selection_stats(mask, evals)
    return {"pos": pos, "feats": feats, "evals": evals, "mask": mask,
            "survivors": survivors, "n_straggler": n_straggler,
            "t_done": t_done, "alive_at_done": alive_at_done,
            "n_active": n_active,
            "n_selected": stats["n_selected"],
            "n_survivor": survivors.sum(),
            "mean_eval_selected": stats["mean_eval_selected"],
            "elect_overflow": elect_overflow}


@functools.partial(jax.jit, static_argnames=("cfg",))
def selection_prefix(st: RoundStatics, params: Params, rnd: jax.Array,
                     sel_key: jax.Array, net_key: jax.Array, *,
                     cfg: StageConfig) -> Dict[str, jax.Array]:
    """The probe -> evaluate -> select -> deadline prefix as ONE compiled
    function: no host round-trips between stages.  ``rnd`` is a traced
    int32 scalar, so every round shares a single executable."""
    return _prefix(st, params, rnd, sel_key, net_key, cfg=cfg)


def _prefix_seeds_body(st: RoundStatics, params: Params,
                       rnd: jax.Array, sel_keys: jax.Array,
                       net_keys: jax.Array, *,
                       cfg: StageConfig) -> Dict[str, jax.Array]:
    return jax.vmap(
        lambda s, p, ks, kn: _prefix(s, p, rnd, ks, kn, cfg=cfg)
    )(st, params, sel_keys, net_keys)


selection_prefix_seeds = functools.partial(
    jax.jit, static_argnames=("cfg",))(_prefix_seeds_body)
selection_prefix_seeds.__doc__ = """The prefix vmapped across a leading
seed axis.

``st``/``params`` carry stacked ``(S, ...)`` leaves (one slice per
seed — same shapes, different data/partitions), ``sel_keys``/
``net_keys`` are ``(S,)``-leading key arrays.  One dispatch evaluates
all S seeds' selection stages for round ``rnd``."""

# The round-ahead sweep scheduler re-stacks the per-seed params every
# round (a fresh (S, ...) buffer per dispatch) — donating them lets XLA
# reuse that allocation for the prefix's intermediates instead of
# round-tripping ~S x model_bytes through fresh buffers each round.
# Only for callers whose stacked params are single-use; the plain
# variant above keeps its inputs alive.
selection_prefix_seeds_donated = functools.partial(
    jax.jit, static_argnames=("cfg",),
    donate_argnums=(1,))(_prefix_seeds_body)


def stack_statics(statics: Sequence[RoundStatics]) -> RoundStatics:
    """Stack per-seed statics into one (S, ...)-leading pytree for
    ``selection_prefix_seeds`` (shapes must match across seeds — they do
    whenever the seeds share a partition profile)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *statics)


# --------------------------------------------------------------------------
# training stages (host gather -> device train -> aggregate)
# --------------------------------------------------------------------------

def cohort_bucket(k: int) -> int:
    """Cohort tensor size for k survivors: next multiple of 2, min 2 —
    jit compiles a handful of shapes no matter how the per-round
    selection count fluctuates.  The floor matters for capacity groups:
    a Table-3 big-group cohort of 1-2 must not train (and compile) 4
    padded 4500-sample slots."""
    return max(2, k + (k % 2))


def train_groups(params: Params, groups: Sequence[ClientGroup],
                 group_steps: Sequence[int], survivors: np.ndarray,
                 keys: jax.Array, *, epochs: int, batch_size: int,
                 lr: float, prox_mu: float, return_entries: bool = False
                 ) -> Optional[Tuple]:
    """Local-training stage (Eq. 1): one ``vmap(local_train)`` per
    capacity group over that group's surviving cohort.

    ``survivors`` is the single host-side crossing of the round — the
    cohort gather needs concrete indices to slice fixed-shape stacks.
    Returns ``(stacked models, weights)`` with padding duplicates at
    weight zero, or ``None`` for an empty round (no-op broadcast).
    Groups with an empty cohort are skipped — never padded from a
    nonexistent ``cohort[0]``.

    The cohort tensors gathered here are fresh per call, so the trainer
    runs with ``donate_argnums`` on them — the (bucket, cap, ...)
    stacks' buffers are recycled into the trained-model outputs instead
    of round-tripping through new allocations every round.

    ``return_entries=True`` (the event-driven server's pool path)
    returns ``(merged, weights (np), client_ids (np))`` instead — the
    per-row global client ids let the caller split the stack's FedAvg
    weights across aggregation ticks without re-gathering (padding rows
    keep weight zero and duplicate the cohort head's id)."""
    if not survivors.any():
        return None
    stacks, weights, row_ids = [], [], []
    for gi, g in enumerate(groups):
        cohort = np.where(survivors[g.client_ids])[0]       # group-local
        k = len(cohort)
        if k == 0:
            continue                         # empty cohort: skip group
        bucket = cohort_bucket(k)
        idx = np.concatenate([cohort, np.full(bucket - k, cohort[0])])
        stacked, _ = local_train_batch_donated(
            params, jnp.asarray(g.images[idx]), jnp.asarray(g.labels[idx]),
            jnp.asarray(g.n_valid[idx]),
            keys[jnp.asarray(g.client_ids[idx])],
            epochs=epochs, batch_size=batch_size,
            steps_per_epoch=group_steps[gi], lr=lr, prox_mu=prox_mu)
        w = g.n_valid[idx].astype(np.float32)
        w[k:] = 0.0                          # padding duplicates drop out
        stacks.append(stacked)
        weights.append(w)
        row_ids.append(g.client_ids[idx])
    merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *stacks)
    if return_entries:
        return merged, np.concatenate(weights), np.concatenate(row_ids)
    return merged, jnp.asarray(np.concatenate(weights))


# the merged (sum-of-buckets, ...) model stack is the round's largest
# fresh buffer (bucket x ~1.66M floats) — donate it into the FedAvg
_fedavg_masked_donated = jax.jit(
    lambda merged, weights: fedavg_masked(merged, weights),
    donate_argnums=(0,))


def aggregate(params: Params,
              trained: Optional[Tuple[Params, jax.Array]]) -> Params:
    """FedAvg stage (Eq. 2) over the survivors; an empty round returns
    the global model unchanged (no-op broadcast).  The merged per-group
    stacks are single-use, so they are donated into the average."""
    if trained is None:
        return params
    merged, weights = trained
    return _fedavg_masked_donated(merged, weights)


# --------------------------------------------------------------------------
# mesh-sharded client axis (shard_map over a ("clients",) mesh)
#
# The same staged prefix, partitioned: every client-axis array (statics
# leaves, random fields, stage intermediates) lives as one shard per
# device, padded with masked dummy clients to a mesh multiple.  The few
# genuinely global steps are explicit collectives:
#
#   - the packed Eq. 7 probe reduces per-client loss sums with a psum
#     (each client's samples live wholly on its owner shard, so the psum
#     only adds exact zeros from the other devices — bitwise-neutral);
#   - the Eq. 8 column maxima are a pmax (max is associativity-exact);
#   - selection (DCS neighbour election windows / CCS quotas / stats)
#     runs on all_gather'ed (N,) evaluation+position vectors — the only
#     arrays that cross devices are N floats, never the (S, 28, 28, 1)
#     probe stacks or the per-group training tensors.
#
# PRNG parity: the channel/loss randomness is drawn as *global fields*
# with exactly the keys and shapes of the unsharded prefix
# (fl/network.py `*_from_fields` split), then padded and sharded like any
# other client-axis array — so a sharded round reproduces the
# single-device selection masks bit-for-bit (pinned in
# tests/test_sharding.py).
# --------------------------------------------------------------------------


def mesh_client_shards(mesh: Optional[Mesh]) -> int:
    """The client-axis partition factor of ``mesh`` (1 when unsharded)."""
    return mesh_axis_size(mesh, CLIENT_AXIS)


def active_client_mesh() -> Optional[Mesh]:
    """The ambient ``logical_sharding`` mesh iff it has a live
    ``clients`` axis — the launchers' ``--mesh clients=K`` activates one;
    unit tests and the single-device drivers see None."""
    mesh = current_mesh()
    return mesh if mesh_client_shards(mesh) > 1 else None


def pad_to_shards(n: int, shards: int) -> int:
    """Client count padded up to a mesh multiple (masked dummy clients —
    never a silent replicate-on-indivisible fallback)."""
    return -(-n // shards) * shards


@functools.lru_cache(maxsize=None)
def _sharded_prefix_fn(cfg: StageConfig, mesh: Mesh, seeds: bool):
    """Build (and cache) the jitted shard_map'd prefix for one
    (StageConfig, mesh) pair.  ``seeds=True`` vmaps the per-shard body
    over a leading seed axis inside the same shard_map — the sweep's
    multi-seed dispatch with every seed's client axis partitioned."""
    k = mesh_client_shards(mesh)
    n = cfg.n_clients
    n_pad = pad_to_shards(n, k)
    shard_n = n_pad // k
    pad = n_pad - n
    table, levels = _rules()

    def core(x0, speeds, jphase, slowdown, n_valid, pim, plb, pseg, counts,
             means, sigmas, centers, params, t_s, k_sel, pin_shadow,
             loss_u, up_shadow):
        """Per-device body: all (shard_n,)-leading arrays are this
        device's client shard; params/counts/membership params are
        replicated; ``pin_shadow``/``loss_u``/``up_shadow`` are the
        device's slice of the globally-drawn random fields."""
        i = jax.lax.axis_index(CLIENT_AXIS)
        gid = i * shard_n + jnp.arange(shard_n)
        valid = gid < n                      # False on dummy pad clients

        # stage: positions + raw features (elementwise in the shard)
        pos = positions_jax(x0, speeds, jphase, t_s,
                            road_length_m=cfg.road_length_m,
                            speed_jitter=cfg.speed_jitter)
        ta = predicted_throughput_from_fields(cfg.network, pos, pin_shadow,
                                              loss_u)
        # Eq. 7 over the local probe shard; every client's samples live
        # on its owner device, so the psum adds exact zeros elsewhere.
        # The fused fast path swaps in the fused probe op (one Pallas
        # launch per shard on TPU; the psum seam below and the Eq. 8
        # pmax stay outside the kernel by design).
        if cfg.fused_probe:
            lf_part = kops.probe_loss(params, pim, plb, pseg, counts,
                                      n_clients=n, batch=cfg.probe_batch)
        else:
            lf_part = dataset_loss_packed(params, pim, plb, pseg, counts,
                                          n_clients=n,
                                          batch=cfg.probe_batch)
        lf_full = jax.lax.psum(lf_part, CLIENT_AXIS)
        lf = jax.lax.dynamic_slice_in_dim(jnp.pad(lf_full, (0, pad)),
                                          i * shard_n, shard_n)
        feats = jnp.stack([n_valid, ta, 1.0 / slowdown, lf],
                          axis=1).astype(jnp.float32)

        # stage: fuzzy evaluation with the Eq. 8 maxima pmax'd globally
        col_max = jax.lax.pmax(
            jnp.where(valid[:, None], feats, -jnp.inf).max(axis=0),
            CLIENT_AXIS)
        evals = kops.fuzzy_eval(feats, means, sigmas, table, levels,
                                centers, normalize=True, col_maxima=col_max)
        evals = jnp.where(valid, evals, 0.0)

        # churn stage (statically gated, exactly like the unsharded
        # prefix): departed clients report no evaluation and cannot be
        # selected; the active mask gathers with the evals so the
        # selection sees the identical (N,) inputs
        if cfg.churn_rate > 0.0:
            active = coverage_active(pos, road_length_m=cfg.road_length_m,
                                     churn_rate=cfg.churn_rate)
            evals = jnp.where(active, evals, 0.0)

        # stage: selection.  elect="windowed" keeps the election
        # shard-local — segment re-bucketing + a ppermute halo ring for
        # the DCS window, a hierarchical top-k for the CCS quota, and
        # psum'd stats — so no (N,) vector is ever gathered.  The gather
        # seam below remains the fallback (and the bit-identity anchor:
        # a non-zero overflow flag makes the round driver re-run the
        # round through it).
        scheme = get_scheme(cfg.scheme)
        windowed = None
        if cfg.elect == "windowed" and scheme.select_sharded is not None:
            ctx = ShardCtx(axis=CLIENT_AXIS, n=n, n_shards=k,
                           shard_n=shard_n, pad=pad, gid=gid, valid=valid)
            windowed = scheme.select_sharded(cfg, ctx, pos, evals, k_sel)
        if windowed is not None:
            mask, ovf_local = windowed
            mask = jnp.where(valid, mask, 0)
            if cfg.churn_rate > 0.0:
                mask = jnp.where(active, mask, 0)
            elect_overflow = jax.lax.pmax(ovf_local, CLIENT_AXIS)
            n_sel = jax.lax.psum(mask.sum(), CLIENT_AXIS)
            ev_sel = jax.lax.psum((evals * mask).sum(), CLIENT_AXIS)
            mean_ev_sel = jnp.where(n_sel > 0,
                                    ev_sel / jnp.maximum(n_sel, 1), 0.0)
        else:
            ev_g = jax.lax.all_gather(evals, CLIENT_AXIS, tiled=True)[:n]
            pos_g = jax.lax.all_gather(pos, CLIENT_AXIS, tiled=True)[:n]
            mask_g = select(cfg, pos_g, ev_g, k_sel)
            if cfg.churn_rate > 0.0:
                act_g = jax.lax.all_gather(active, CLIENT_AXIS,
                                           tiled=True)[:n]
                mask_g = jnp.where(act_g, mask_g, 0)
            mask = jax.lax.dynamic_slice_in_dim(jnp.pad(mask_g, (0, pad)),
                                                i * shard_n, shard_n)
            elect_overflow = jnp.int32(0)
            stats = selection_stats(mask_g, ev_g)
            n_sel = stats["n_selected"]
            mean_ev_sel = stats["mean_eval_selected"]

        # stage: Eq. 6 deadline, shard-local again
        train_t = training_time_s(cfg.timing, slowdown, n_valid)
        upload_t = upload_time_s_from_shadow(cfg.network, pos,
                                             cfg.model_bytes, up_shadow)
        ok = completes_before_deadline(cfg.timing, train_t, upload_t)
        selected = mask > 0
        survivors = selected & ok & valid
        n_straggler = jax.lax.psum((selected & ~ok & valid).sum(),
                                   CLIENT_AXIS)
        n_survivor = jax.lax.psum(survivors.sum(), CLIENT_AXIS)
        # event-server inputs, shard-local like the deadline stage
        t_done = t_s + train_t + upload_t
        if cfg.churn_rate > 0.0:
            pos_done = positions_jax(x0, speeds, jphase, t_done,
                                     road_length_m=cfg.road_length_m,
                                     speed_jitter=cfg.speed_jitter)
            alive_done = coverage_active(pos_done,
                                         road_length_m=cfg.road_length_m,
                                         churn_rate=cfg.churn_rate)
            n_active = jax.lax.psum((active & valid).sum(), CLIENT_AXIS)
        else:
            alive_done = jnp.ones_like(survivors)
            n_active = jnp.asarray(n, jnp.int32)
        return (pos, feats, evals, mask, survivors, n_straggler,
                t_done, alive_done, n_active,
                n_sel, n_survivor, mean_ev_sel, elect_overflow)

    def s(*tail):
        """Spec helper: prepend the (unsharded) seed axis when vmapped."""
        return P(None, *tail) if seeds else P(*tail)

    rep = P()
    in_specs = (s(CLIENT_AXIS), s(CLIENT_AXIS), s(CLIENT_AXIS),
                s(CLIENT_AXIS), s(CLIENT_AXIS),
                s(CLIENT_AXIS, None, None, None),    # probe images
                s(CLIENT_AXIS), s(CLIENT_AXIS),      # probe labels/seg
                rep, rep, rep, rep,                  # counts, memberships
                rep, rep, rep,                       # params, t_s, k_sel
                P(CLIENT_AXIS),                      # pinned shadow
                s(None, CLIENT_AXIS),                # cwnd loss field
                s(CLIENT_AXIS))                      # upload shadow
    out_specs = (s(CLIENT_AXIS), s(CLIENT_AXIS, None), s(CLIENT_AXIS),
                 s(CLIENT_AXIS), s(CLIENT_AXIS), rep,
                 s(CLIENT_AXIS), s(CLIENT_AXIS), rep,
                 rep, rep, rep, rep)
    body = core if not seeds else jax.vmap(
        core, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
                       None, 0, None, 0, 0))
    sharded = shard_map(body, mesh, in_specs=in_specs, out_specs=out_specs,
                        check_rep=False)

    def run(st: RoundStatics, params: Params, rnd: jax.Array,
            sel_key: jax.Array, net_key: jax.Array):
        sample_ax = 1 if seeds else 0
        if st.probe_images.shape[sample_ax] % k != 0:
            raise ValueError(
                f"packed probe sample axis {st.probe_images.shape} not "
                f"divisible by {k} client shards — build the simulation "
                f"inside the mesh context so the probe packs per shard")
        t_s = rnd.astype(jnp.float32) * cfg.timing.deadline_s
        # per-round keys + global random fields, folded/drawn exactly as
        # the unsharded prefix folds/draws them (see _prefix)
        if seeds:
            k_sel = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
                sel_key, rnd)
            folded = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
                net_key, rnd)
            knet = jax.vmap(jax.random.split)(folded)
            loss_u = jax.vmap(lambda kk: cwnd_loss_fields(kk, n))(
                knet[:, 0])
            up_shadow = jax.vmap(lambda kk: jax.random.normal(kk, (n,)))(
                knet[:, 1])
        else:
            k_sel = jax.random.fold_in(sel_key, rnd)
            k_pred, k_upload = jax.random.split(
                jax.random.fold_in(net_key, rnd))
            loss_u = cwnd_loss_fields(k_pred, n)
            up_shadow = jax.random.normal(k_upload, (n,))
        pin_shadow = jnp.pad(pinned_channel_shadow(n), (0, pad))

        ax = 1 if seeds else 0

        def padc(x, value=0.0, axis=ax):
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad)
            return jnp.pad(x, widths, constant_values=value)

        out = sharded(
            padc(st.x0), padc(st.speeds), padc(st.jitter_phase),
            padc(st.slowdown, 1.0), padc(st.n_valid),
            st.probe_images, st.probe_labels, st.probe_seg,
            st.probe_counts, st.means, st.sigmas, st.level_centers,
            params, t_s, k_sel, pin_shadow,
            padc(loss_u, axis=loss_u.ndim - 1), padc(up_shadow))
        (pos, feats, evals, mask, survivors, n_strag, t_done, alive,
         n_active, n_sel, n_surv, mev, ovf) = out
        cut = (lambda x: x[:, :n]) if seeds else (lambda x: x[:n])
        res = {"pos": cut(pos), "feats": cut(feats), "evals": cut(evals),
               "mask": cut(mask), "survivors": cut(survivors),
               "n_straggler": n_strag, "t_done": cut(t_done),
               "alive_at_done": cut(alive), "n_active": n_active,
               "n_selected": n_sel, "n_survivor": n_surv,
               "mean_eval_selected": mev, "elect_overflow": ovf}
        if multihost:
            # every process consumes the full round state (masks feed the
            # host-side cohort gather on each host) — replicate outputs
            # so device_get works everywhere
            res = {key: jax.lax.with_sharding_constraint(
                v, NamedSharding(mesh, P())) for key, v in res.items()}
        return res

    multihost = mesh_is_multihost(mesh)
    return jax.jit(run)


def selection_prefix_sharded(st: RoundStatics, params: Params,
                             rnd: jax.Array, sel_key: jax.Array,
                             net_key: jax.Array, *, cfg: StageConfig,
                             mesh: Mesh) -> Dict[str, jax.Array]:
    """``selection_prefix`` with the client axis partitioned over
    ``mesh``'s ``clients`` axis — same signature, same output dict, same
    masks bit-for-bit; requires the statics' probe packed for the mesh
    (``FLSimulation`` built inside the mesh context does this)."""
    return _sharded_prefix_fn(cfg, mesh, False)(st, params, rnd, sel_key,
                                                net_key)


def selection_prefix_seeds_sharded(st: RoundStatics, params: Params,
                                   rnd: jax.Array, sel_keys: jax.Array,
                                   net_keys: jax.Array, *, cfg: StageConfig,
                                   mesh: Mesh) -> Dict[str, jax.Array]:
    """``selection_prefix_seeds`` over a client mesh: one dispatch
    evaluates S seeds' selection stages with every seed's client axis
    sharded over the same devices."""
    return _sharded_prefix_fn(cfg, mesh, True)(st, params, rnd, sel_keys,
                                               net_keys)


# -- sharded training stages ------------------------------------------------

def cohort_bucket_sharded(k: int, shards: int) -> int:
    """``cohort_bucket`` rounded up to a mesh multiple, so every device
    trains an equal slice of the group's cohort (padding duplicates at
    weight zero, exactly like the unsharded bucket)."""
    return pad_to_shards(cohort_bucket(k), shards)


@functools.lru_cache(maxsize=None)
def _sharded_group_trainer(mesh: Mesh, epochs: int, batch_size: int,
                           steps_per_epoch: int, lr: float, prox_mu: float):
    """One capacity group's shard_map'd trainer: each device runs
    ``local_train_batch`` over its cohort shard and the weighted model
    sum finishes with a cross-device psum (``fedavg_sums``) — the
    ``(bucket, cap, ...)`` stack never materializes on one chip."""

    def body(params, images, labels, n_valid, keys, w):
        stacked, _ = local_train_batch(
            params, images, labels, n_valid, keys, epochs=epochs,
            batch_size=batch_size, steps_per_epoch=steps_per_epoch, lr=lr,
            prox_mu=prox_mu)
        return fedavg_sums(stacked, w, axis_name=CLIENT_AXIS)

    c = P(CLIENT_AXIS)
    sharded = shard_map(body, mesh, in_specs=(P(), c, c, c, c, c),
                        out_specs=(P(), P()), check_rep=False)
    # the cohort shards are device_put fresh per round by the gather
    # below — donate them so the per-device training buffers recycle
    return jax.jit(sharded, donate_argnums=(1, 2, 3, 4, 5))


def train_group_cohort_sharded(params: Params, group: ClientGroup,
                               steps_per_epoch: int, idx: np.ndarray,
                               weights: np.ndarray, keys: jax.Array,
                               mesh: Mesh, *, epochs: int, batch_size: int,
                               lr: float, prox_mu: float
                               ) -> Tuple[Params, jax.Array]:
    """Dispatch one group's gathered cohort to the sharded trainer.

    The host-side gather places each device's shard directly via
    ``NamedSharding`` (``resolve_pspec`` with ``require=`` — the client
    partition may never silently replicate), so only ``len(idx)/K``
    clients' tensors are ever transferred to any one device.  Returns the
    psum'd ``(weighted model sum, weight total)`` partial aggregates."""
    rules = {CLIENT_AXIS: CLIENT_AXIS}
    images = group.images[idx]
    im_spec = resolve_pspec(mesh, rules, (CLIENT_AXIS,) + (None,) *
                            (images.ndim - 1), images.shape,
                            require=(CLIENT_AXIS,))
    row_spec = resolve_pspec(mesh, rules, (CLIENT_AXIS,), (len(idx),),
                             require=(CLIENT_AXIS,))

    def put(a, spec):
        return jax.device_put(a, NamedSharding(mesh, spec))

    trainer = _sharded_group_trainer(mesh, epochs, batch_size,
                                     steps_per_epoch, lr, prox_mu)
    return trainer(params, put(images, im_spec),
                   put(group.labels[idx], row_spec),
                   put(group.n_valid[idx], row_spec),
                   put(np.asarray(keys), row_spec),
                   put(weights.astype(np.float32), row_spec))


def train_groups_sharded(params: Params, groups: Sequence[ClientGroup],
                         group_steps: Sequence[int], survivors: np.ndarray,
                         keys: jax.Array, mesh: Mesh, *, epochs: int,
                         batch_size: int, lr: float, prox_mu: float,
                         weight_scale: float = 1.0
                         ) -> Optional[Tuple[Params, jax.Array]]:
    """Mesh-sharded ``train_groups``: per capacity group, each device
    trains its shard of the surviving cohort; the Eq. 2 numerator/
    denominator accumulate across groups and devices (psum inside the
    trainer, plain adds across groups).  Returns the unnormalized
    ``(sum_i w_i model_i, sum_i w_i)`` or None for an empty round.

    ``weight_scale`` multiplies every cohort weight — the event-driven
    server's per-tick staleness factor (one landing tick shares one
    delay, hence one scalar).  The default 1.0 leaves the weights
    bitwise untouched (the sync-parity pin)."""
    if not survivors.any():
        return None
    shards = mesh_client_shards(mesh)
    num_tot, den_tot = None, None
    for gi, g in enumerate(groups):
        cohort = np.where(survivors[g.client_ids])[0]       # group-local
        k = len(cohort)
        if k == 0:
            continue                         # empty cohort: skip group
        bucket = cohort_bucket_sharded(k, shards)
        idx = np.concatenate([cohort, np.full(bucket - k, cohort[0])])
        w = g.n_valid[idx].astype(np.float32)
        if weight_scale != 1.0:
            w *= np.float32(weight_scale)
        w[k:] = 0.0                          # padding duplicates drop out
        num, den = train_group_cohort_sharded(
            params, g, group_steps[gi], idx, w,
            keys[jnp.asarray(g.client_ids[idx])], mesh, epochs=epochs,
            batch_size=batch_size, lr=lr, prox_mu=prox_mu)
        num_tot = num if num_tot is None else jax.tree.map(jnp.add,
                                                           num_tot, num)
        den_tot = den if den_tot is None else den_tot + den
    if num_tot is None:
        return None
    return num_tot, den_tot


def _finish_sharded_aggregate(num: Params, den: jax.Array,
                              params: Params) -> Params:
    inv = 1.0 / jnp.maximum(den, 1e-9)
    return jax.tree.map(lambda s_leaf, p: (s_leaf * inv).astype(p.dtype),
                        num, params)


# the psum'd weighted-sum tree is fresh per round — donate it into the
# normalized global model
_finish_sharded_aggregate_donated = jax.jit(_finish_sharded_aggregate,
                                            donate_argnums=(0,))


def aggregate_sharded(params: Params,
                      trained: Optional[Tuple[Params, jax.Array]]) -> Params:
    """Finish Eq. 2 from the sharded trainer's psum'd partial sums; an
    empty round returns the global model unchanged."""
    if trained is None:
        return params
    num, den = trained
    return _finish_sharded_aggregate_donated(num, den, params)
