"""Client-selection scheme registry (paper Alg. 1 step 4, pluggable).

The three paper schemes — DCS neighbour election, centralized fuzzy
top-n, centralized uniform random — used to be a hard-coded three-way
string match inside ``fl/pipeline.select`` (and a parallel overhead-key
dict in ``fl/rounds.py``).  This registry makes them data: a scheme is a
name bound to a pure selection function plus the §4.2 communication-
accounting key, and future schemes (FedCLF-style calibrated selection,
FairEquityFL quotas, ...) plug in with ``register_scheme`` without
touching the pipeline.

A scheme's ``select`` function must be jax-traceable (it runs inside the
jitted selection prefix, including its vmapped and shard_map'd forms)
with signature ``(cfg: StageConfig, pos (N,), evals (N,), key) -> (N,)
int32 mask``.  ``overhead_key`` picks the ``core/overhead.py``
accumulated-time model: ``"cfl"`` maintains classical full client state
(the random baseline), ``"ccs-fuzzy"`` exchanges evaluations via the
cloud, ``"dcs"`` exchanges evaluations over DSRC.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax

from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select)

# (cfg, pos, evals, sel_key) -> int32 mask (N,)
SelectFn = Callable[[Any, jax.Array, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """One registered selection scheme."""
    name: str
    select: SelectFn
    overhead_key: str             # core/overhead.py accumulated-time key


_REGISTRY: Dict[str, Scheme] = {}


def register_scheme(name: str, fn: SelectFn, *,
                    overhead_key: str = "ccs-fuzzy",
                    overwrite: bool = False) -> Scheme:
    """Register ``fn`` as selection scheme ``name``.

    Re-registering an existing name raises unless ``overwrite=True`` —
    silent replacement of a builtin would skew every consumer of the
    registry (pipeline, simulator, sweep CLI) at a distance."""
    if not name or not isinstance(name, str):
        raise ValueError(f"scheme name must be a non-empty str: {name!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {name!r} is already registered "
                         f"(pass overwrite=True to replace)")
    scheme = Scheme(name=name, select=fn, overhead_key=overhead_key)
    _REGISTRY[name] = scheme
    return scheme


def get_scheme(name: str) -> Scheme:
    """Look up a registered scheme; unknown names raise with the list."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown selection scheme {name!r} "
            f"(registered: {', '.join(scheme_names())})") from None


def scheme_names() -> Tuple[str, ...]:
    """Registered scheme names, registration order."""
    return tuple(_REGISTRY)


# -- the paper's three schemes ----------------------------------------------

def _dcs(cfg, pos, evals, sel_key):
    return dcs_select(pos, evals, comm_range=cfg.comm_range_m,
                      top_m=cfg.top_m, e_tau=cfg.e_tau)


def _ccs_fuzzy(cfg, pos, evals, sel_key):
    return ccs_fuzzy_select(evals, cfg.n_clients_central)


def _ccs_random(cfg, pos, evals, sel_key):
    return ccs_random_select(sel_key, cfg.n_clients, cfg.n_clients_central)


register_scheme("dcs", _dcs, overhead_key="dcs")
register_scheme("ccs-fuzzy", _ccs_fuzzy, overhead_key="ccs-fuzzy")
register_scheme("random", _ccs_random, overhead_key="cfl")
