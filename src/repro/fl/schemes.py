"""Client-selection scheme registry (paper Alg. 1 step 4, pluggable).

The three paper schemes — DCS neighbour election, centralized fuzzy
top-n, centralized uniform random — used to be a hard-coded three-way
string match inside ``fl/pipeline.select`` (and a parallel overhead-key
dict in ``fl/rounds.py``).  This registry makes them data: a scheme is a
name bound to a pure selection function plus the §4.2 communication-
accounting key, and future schemes (FedCLF-style calibrated selection,
FairEquityFL quotas, ...) plug in with ``register_scheme`` without
touching the pipeline.

A scheme's ``select`` function must be jax-traceable (it runs inside the
jitted selection prefix, including its vmapped and shard_map'd forms)
with signature ``(cfg: StageConfig, pos (N,), evals (N,), key) -> (N,)
int32 mask``.  ``overhead_key`` picks the ``core/overhead.py``
accumulated-time model: ``"cfl"`` maintains classical full client state
(the random baseline), ``"ccs-fuzzy"`` exchanges evaluations via the
cloud, ``"dcs"`` exchanges evaluations over DSRC.

Two optional fast paths back the windowed election (ISSUE 9):

- ``select_windowed(cfg, pos, evals, key) -> (mask, overflow) | None``
  replaces the O(N^2) sweep on a single device with an O(N * W)
  position-sorted window; returning ``None`` (at trace time) means "no
  windowed form, use ``select``".
- ``select_sharded(cfg, ctx, pos, evals, key) -> (mask, overflow) |
  None`` runs *inside* the client-sharded ``shard_map`` on per-shard
  arrays and must return the local shard's mask without ever
  materialising the gathered (N,) vectors.  ``ctx`` is a ``ShardCtx``.
  Returning ``None`` means the configuration is infeasible for this
  scheme (e.g. the DCS halo ring needs ``2*hops + 1 <= K``) and the
  prefix falls back to the gather seam.

Both paths carry a runtime ``overflow`` int32: non-zero signals a fixed
window/buffer could not hold every comparison the dense election would
make, and the round driver re-runs that round through the gather path —
so windowed masks are bit-identical to the full election whenever used.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import elect as celect
from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select, dcs_select_windowed)

# (cfg, pos, evals, sel_key) -> int32 mask (N,)
SelectFn = Callable[[Any, jax.Array, jax.Array, jax.Array], jax.Array]
# (cfg, pos, evals, sel_key) -> (mask, overflow) or None
WindowedFn = Callable[..., Optional[Tuple[jax.Array, jax.Array]]]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Per-shard context handed to ``select_sharded`` inside shard_map.

    ``gid``/``valid`` are the shard's (shard_n,) global client ids and
    real-client mask (padding slots are invalid); ``pad`` is the global
    padding ``n_shards * shard_n - n``."""
    axis: str
    n: int
    n_shards: int
    shard_n: int
    pad: int
    gid: jax.Array
    valid: jax.Array


@dataclasses.dataclass(frozen=True)
class Scheme:
    """One registered selection scheme."""
    name: str
    select: SelectFn
    overhead_key: str             # core/overhead.py accumulated-time key
    select_windowed: Optional[WindowedFn] = None
    select_sharded: Optional[WindowedFn] = None


_REGISTRY: Dict[str, Scheme] = {}


def register_scheme(name: str, fn: SelectFn, *,
                    overhead_key: str = "ccs-fuzzy",
                    overwrite: bool = False,
                    select_windowed: Optional[WindowedFn] = None,
                    select_sharded: Optional[WindowedFn] = None) -> Scheme:
    """Register ``fn`` as selection scheme ``name``.

    Re-registering an existing name raises unless ``overwrite=True`` —
    silent replacement of a builtin would skew every consumer of the
    registry (pipeline, simulator, sweep CLI) at a distance."""
    if not name or not isinstance(name, str):
        raise ValueError(f"scheme name must be a non-empty str: {name!r}")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {name!r} is already registered "
                         f"(pass overwrite=True to replace)")
    scheme = Scheme(name=name, select=fn, overhead_key=overhead_key,
                    select_windowed=select_windowed,
                    select_sharded=select_sharded)
    _REGISTRY[name] = scheme
    return scheme


def get_scheme(name: str) -> Scheme:
    """Look up a registered scheme; unknown names raise with the list."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown selection scheme {name!r} "
            f"(registered: {', '.join(scheme_names())})") from None


def scheme_names() -> Tuple[str, ...]:
    """Registered scheme names, registration order."""
    return tuple(_REGISTRY)


def elect_window(cfg) -> int:
    """The config's sorted-neighbour window (0 = auto-sized)."""
    return cfg.elect_window or celect.auto_window(
        cfg.n_clients, cfg.comm_range_m, cfg.road_length_m)


def elect_capacity(cfg, shard_n: int, n_shards: int) -> int:
    """The config's per-(shard -> segment) bucket capacity (0 = auto)."""
    return cfg.elect_capacity or celect.auto_capacity(shard_n, n_shards)


# -- the paper's three schemes ----------------------------------------------

def _dcs(cfg, pos, evals, sel_key):
    return dcs_select(pos, evals, comm_range=cfg.comm_range_m,
                      top_m=cfg.top_m, e_tau=cfg.e_tau)


def _dcs_windowed(cfg, pos, evals, sel_key):
    return dcs_select_windowed(pos, evals, comm_range=cfg.comm_range_m,
                               top_m=cfg.top_m, e_tau=cfg.e_tau,
                               window=elect_window(cfg))


def _dcs_sharded(cfg, ctx, pos, evals, sel_key):
    k = ctx.n_shards
    if k < 2:
        return None
    hops = celect.ring_hops(cfg.comm_range_m, cfg.road_length_m, k)
    if 2 * hops + 1 > k:
        return None                # halo ring would lap itself -> gather
    return celect.ring_halo_elect(
        pos, evals, ctx.gid, ctx.valid, axis=ctx.axis, n=ctx.n,
        n_shards=k, shard_n=ctx.shard_n, comm_range=cfg.comm_range_m,
        top_m=cfg.top_m, e_tau=cfg.e_tau, road_length=cfg.road_length_m,
        window=elect_window(cfg),
        capacity=elect_capacity(cfg, ctx.shard_n, k))


def _ccs_fuzzy(cfg, pos, evals, sel_key):
    return ccs_fuzzy_select(evals, cfg.n_clients_central)


def _ccs_fuzzy_sharded(cfg, ctx, pos, evals, sel_key):
    if ctx.n_shards < 2:
        return None
    mask = celect.sharded_topk_mask(
        evals, ctx.gid, ctx.valid, axis=ctx.axis, n=ctx.n,
        shard_n=ctx.shard_n, k_top=min(cfg.n_clients_central, ctx.n))
    return mask, jnp.int32(0)


def _ccs_random(cfg, pos, evals, sel_key):
    return ccs_random_select(sel_key, cfg.n_clients, cfg.n_clients_central)


def _ccs_random_sharded(cfg, ctx, pos, evals, sel_key):
    # the draw only needs the key: compute the full mask replicated (it
    # is O(N) bits of identical work per device, no collectives) and
    # slice out this shard
    full = ccs_random_select(sel_key, cfg.n_clients, cfg.n_clients_central)
    padded = jnp.pad(full, (0, ctx.pad))
    i = jax.lax.axis_index(ctx.axis)
    mask = jax.lax.dynamic_slice_in_dim(padded, i * ctx.shard_n,
                                        ctx.shard_n)
    return mask, jnp.int32(0)


register_scheme("dcs", _dcs, overhead_key="dcs",
                select_windowed=_dcs_windowed,
                select_sharded=_dcs_sharded)
register_scheme("ccs-fuzzy", _ccs_fuzzy, overhead_key="ccs-fuzzy",
                select_sharded=_ccs_fuzzy_sharded)
register_scheme("random", _ccs_random, overhead_key="cfl",
                select_sharded=_ccs_random_sharded)
