"""Training-time model and straggler handling (paper §5.2, Eq. 6).

Eq. 6 as printed —  T = E*C_i*|D_i| / (B_size*B_exe)  — is dimensionally
inconsistent with the paper's own definition of B_exe ("the time to train
the model ... for B_size samples", 0.06 s): dividing by seconds yields
1/s.  We implement the dimensionally consistent reading

    T_i = E * C_i * |D_i| * B_exe / B_size                    [seconds]

where C_i >= 1 is the *slowdown* ratio of vehicle i relative to the
reference machine that measured B_exe (C_i = 1/capability).  With the
paper's Table 3 values this gives big vehicles (4500 samples, E=30,
B=20, B_exe=0.06 s) T = 405 s at C_i=1 — far beyond the 20 s deadline,
which is why the deadline/straggler mechanism and per-round epoch budget
matter; the simulator makes E configurable.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TimingConfig:
    epochs: int = 30
    batch_size: int = 20
    b_exe_s: float = 0.06          # measured on the paper's i5 reference
    deadline_s: float = 20.0


def training_time_s(cfg: TimingConfig, slowdown: np.ndarray,
                    n_samples: np.ndarray) -> np.ndarray:
    """T_i = E * C_i * |D_i| * B_exe / B_size  (vectorized)."""
    return (cfg.epochs * slowdown * n_samples * cfg.b_exe_s
            / cfg.batch_size)


def completes_before_deadline(cfg: TimingConfig, train_s: np.ndarray,
                              upload_s: np.ndarray) -> np.ndarray:
    """Straggler mask: local models arriving after the deadline are
    discarded (paper §6.1)."""
    return (train_s + upload_s) <= cfg.deadline_s


def staleness_weight(lam: float, delay_rounds) -> np.ndarray:
    """Staleness-weighted aggregation weight ``1 / (1 + lambda * d)``
    for an update aggregated ``d`` rounds after the round whose global
    model it was trained from (event-driven server, ISSUE 6).

    ``d = 0`` (on time) always weighs 1; ``lam = 0`` disables the decay
    (every late update counts fully); works on scalars and arrays.  The
    hard-deadline Eq. 6 policy is the ``lam -> inf`` limit restricted to
    {1 at deadline, 0 after} — the event server's "drop" mode pins that
    limit exactly rather than approximating it."""
    if lam < 0.0:
        raise ValueError(f"staleness lambda must be >= 0: {lam}")
    if np.any(np.asarray(delay_rounds) < 0):
        raise ValueError(f"delay_rounds must be >= 0: {delay_rounds}")
    return 1.0 / (1.0 + lam * delay_rounds)


def measure_b_exe(batch_size: int = 20, repeats: int = 3) -> float:
    """Measure B_exe for the paper's CNN on *this* host (DESIGN.md §4)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.models.cnn import cnn_loss, init_cnn
    from repro.train.optim import sgd_update

    params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    imgs = jnp.zeros((batch_size, 28, 28, 1))
    lbls = jnp.zeros((batch_size,), jnp.int32)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(cnn_loss, has_aux=True)(p, imgs, lbls)
        return sgd_update(p, g, 0.01)

    params = step(params)                      # compile
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(repeats):
        params = step(params)
    jax.block_until_ready(params)
    return (time.perf_counter() - t0) / repeats
