"""The federated round engine (paper Alg. 1 + §6 simulator).

One ``FLSimulation`` couples: the synthetic non-iid dataset partition,
freeway mobility, the cellular/CWND network model, the Eq. 6 timing model,
the fuzzy evaluator and one of the three selection schemes.  Each round:

  1. broadcast: every participant receives the global model;
  2. probe: every participant computes Eq. 7 (loss of the *global* model
     over its local data, no update);
  3. evaluate: fuzzy evaluation from (SQ, TA, CC, LF), locally;
  4. select: dcs (neighbour election) / ccs-fuzzy (server top-n) /
     random (server uniform);
  5. train: selected clients run Eq. 1 local SGD;
  6. deadline: models whose train+upload time exceeds the deadline are
     discarded (stragglers);
  7. aggregate: FedAvg (Eq. 2) over the survivors;
  8. account: state-maintenance vs evaluation-exchange communication.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select)
from repro.data.synthetic import make_dataset, train_test_split
from repro.fl.aggregation import fedavg
from repro.fl.client import dataset_loss, evaluate_accuracy, local_train
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig
from repro.fl.partition import PartitionConfig, pad_clients, partition
from repro.fl.timing import TimingConfig, completes_before_deadline, \
    training_time_s
from repro.models.cnn import init_cnn


@dataclass
class FLSimConfig:
    scheme: str = "dcs"                  # dcs | ccs-fuzzy | random
    n_rounds: int = 20
    n_clients_central: int = 5           # CCS/random pick (Table 3)
    comm_range_m: float = 200.0
    top_m: int = 2                       # per 200 m area (Table 3)
    e_tau: float = 30.0
    local_epochs: int = 2                # paper: 30; scaled for CPU budget
    batch_size: int = 20
    lr: float = 0.05
    prox_mu: float = 0.0                 # >0 enables FedProx
    deadline_s: float = 60.0             # see fl/timing.py docstring
    model_bytes: float = 5.2e6
    state_bytes: float = 100.0
    eval_bytes: float = 30.0
    state_interval_s: float = 1.0
    slowdown_range: tuple = (1.0, 4.0)   # C_i heterogeneity
    probe_samples: int = 256             # Eq. 7 subsample (paper: all
                                         # samples; ranking-equivalent)
    samples_per_class: int = 6600        # source pool size (>= per-class
                                         # demand of the no-dup partition)
    seed: int = 0
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    mobility: MobilityConfig = field(default_factory=MobilityConfig)
    network: NetworkConfig = field(default_factory=NetworkConfig)


class FLSimulation:
    def __init__(self, cfg: FLSimConfig,
                 evaluator: Optional[FuzzyEvaluator] = None):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        images, labels = make_dataset(cfg.samples_per_class, seed=cfg.seed)
        (tr_i, tr_l), (te_i, te_l) = train_test_split(images, labels,
                                                      seed=cfg.seed)
        self.test_images = jnp.asarray(te_i)
        self.test_labels = jnp.asarray(te_l)

        parts = partition(tr_i, tr_l, cfg.partition)
        self.n = cfg.partition.n_clients
        # two capacity groups keep the jitted local trainer cheap for the
        # 45-sample vehicles
        big_cap = int(np.ceil(cfg.partition.big_quantity
                              / cfg.batch_size) * cfg.batch_size)
        small_cap = int(np.ceil(max(cfg.partition.small_quantity, cfg.batch_size)
                                / cfg.batch_size) * cfg.batch_size)
        self.caps = np.array([big_cap if len(p[1]) > small_cap else small_cap
                              for p in parts])
        self.images, self.labels, self.n_valid = {}, {}, np.zeros(
            self.n, np.int32)
        padded = {}
        for cap in sorted(set(self.caps)):
            group = [i for i in range(self.n) if self.caps[i] == cap]
            im, lb, nv = pad_clients([parts[i] for i in group], cap)
            for j, i in enumerate(group):
                self.images[i] = jnp.asarray(im[j])
                self.labels[i] = jnp.asarray(lb[j])
                self.n_valid[i] = nv[j]

        self.slowdown = rng.uniform(*cfg.slowdown_range, self.n)
        self.network = CellularNetwork(cfg.network)
        # quality proxy for the 'extreme' placement: big data + fast compute
        quality = (self.n_valid / self.n_valid.max()
                   + 1.0 / self.slowdown)
        self.mobility = FreewayMobility(
            cfg.mobility, quality_rank=np.argsort(-quality))
        self.evaluator = evaluator or FuzzyEvaluator(
            FuzzyEvaluatorConfig(e_tau=cfg.e_tau))
        self.params = init_cnn(jax.random.PRNGKey(cfg.seed), CNN_CFG)
        self.key = jax.random.PRNGKey(cfg.seed + 1)

    # ------------------------------------------------------------------
    def _features(self, pos: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        sq = self.n_valid / max(self.n_valid.max(), 1)
        ta_raw = self.network.predicted_throughput(pos)
        ta = ta_raw / max(ta_raw.max(), 1e-9)
        cc_raw = 1.0 / self.slowdown
        cc = cc_raw / cc_raw.max()
        probe = self.cfg.probe_samples
        lf_raw = np.array([
            float(dataset_loss(
                self.params, self.images[i][:probe], self.labels[i][:probe],
                jnp.int32(min(int(self.n_valid[i]), probe)), batch=128))
            for i in range(self.n)])
        lf = lf_raw / max(lf_raw.max(), 1e-9)
        return np.stack([sq, ta, cc, lf], axis=1).astype(np.float32)

    def _select(self, pos: np.ndarray, evals: jnp.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.scheme == "dcs":
            mask = dcs_select(jnp.asarray(pos), evals,
                              comm_range=cfg.comm_range_m, top_m=cfg.top_m,
                              e_tau=cfg.e_tau)
        elif cfg.scheme == "ccs-fuzzy":
            mask = ccs_fuzzy_select(evals, cfg.n_clients_central)
        elif cfg.scheme == "random":
            self.key, sub = jax.random.split(self.key)
            mask = ccs_random_select(sub, self.n, cfg.n_clients_central)
        else:
            raise ValueError(cfg.scheme)
        return np.asarray(mask)

    def _comm_accounting(self, n_selected: int) -> Dict[str, float]:
        """Per-round communication (bytes and time) per §4.2 / Fig. 9."""
        cfg = self.cfg
        msgs = self.n * cfg.deadline_s / cfg.state_interval_s
        up_bytes = n_selected * cfg.model_bytes
        if cfg.scheme in ("ccs-fuzzy",):
            state_b = msgs * cfg.eval_bytes
            state_t = msgs * 0.2
        elif cfg.scheme == "random":
            state_b = msgs * cfg.state_bytes
            state_t = msgs * 0.2
        else:                                   # dcs: DSRC evaluations only
            state_b = msgs * cfg.eval_bytes
            state_t = msgs * 0.04
        return {"state_bytes": state_b, "upload_bytes": up_bytes,
                "state_time_s": state_t}

    # ------------------------------------------------------------------
    def run_round(self, rnd: int) -> Dict[str, float]:
        cfg = self.cfg
        t = rnd * cfg.deadline_s
        pos = self.mobility.positions(t)
        feats = self._features(pos)
        evals = self.evaluator.evaluate(jnp.asarray(feats))
        mask = self._select(pos, evals)
        sel = np.where(mask > 0)[0]

        # local training (Eq. 1)
        new_models, weights = [], []
        train_t = training_time_s(
            TimingConfig(cfg.local_epochs, cfg.batch_size,
                         deadline_s=cfg.deadline_s),
            self.slowdown, self.n_valid)
        upload_t = self.network.upload_time_s(pos, cfg.model_bytes)
        ok = completes_before_deadline(
            TimingConfig(cfg.local_epochs, cfg.batch_size,
                         deadline_s=cfg.deadline_s), train_t, upload_t)
        n_straggler = 0
        for i in sel:
            if not ok[i]:
                n_straggler += 1
                continue
            self.key, sub = jax.random.split(self.key)
            cap = int(self.caps[i])
            p_i, _ = local_train(
                self.params, self.images[i], self.labels[i],
                jnp.int32(self.n_valid[i]), sub, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size,
                steps_per_epoch=cap // cfg.batch_size, lr=cfg.lr,
                prox_mu=cfg.prox_mu)
            new_models.append(p_i)
            weights.append(float(self.n_valid[i]))

        if new_models:                           # Eq. 2
            self.params = fedavg(new_models, weights)

        acc = evaluate_accuracy(self.params, self.test_images,
                                self.test_labels)
        row = {"round": rnd, "accuracy": acc, "n_selected": len(sel),
               "n_aggregated": len(new_models), "n_straggler": n_straggler,
               "mean_eval_selected": float(
                   evals[sel].mean()) if len(sel) else 0.0}
        row.update(self._comm_accounting(len(sel)))
        return row

    def run(self, n_rounds: Optional[int] = None) -> List[Dict[str, float]]:
        n = n_rounds or self.cfg.n_rounds
        return [self.run_round(r) for r in range(n)]
