"""The federated round engine (paper Alg. 1 + §6 simulator).

One ``FLSimulation`` couples: the synthetic non-iid dataset partition,
freeway mobility, the cellular/CWND network model, the Eq. 6 timing model,
the fuzzy evaluator and one of the three selection schemes.  Each round:

  1. broadcast: every participant receives the global model;
  2. probe: every participant computes Eq. 7 (loss of the *global* model
     over its local data, no update);
  3. evaluate: fuzzy evaluation from (SQ, TA, CC, LF), locally;
  4. select: dcs (neighbour election) / ccs-fuzzy (server top-n) /
     random (server uniform);
  5. train: selected clients run Eq. 1 local SGD;
  6. deadline: models whose train+upload time exceeds the deadline are
     discarded (stragglers);
  7. aggregate: FedAvg (Eq. 2) over the survivors;
  8. account: state-maintenance vs evaluation-exchange communication.

Client datasets are stored **capacity-grouped**: ``stack_clients``
buckets clients by quantity-rounded-to-batches capacity and returns one
fixed-shape ``ClientGroup`` per bucket (Table-3 full profile: a 4500-cap
group of 12 and a 60-cap group of 18).  Two engines implement steps
2/5/7 over these groups:

- ``engine="batched"`` (default): the Eq. 7 probe is one fused forward
  pass over a packed concatenation of every client's valid probe samples
  (padding rows cost nothing), local SGD is one ``vmap(local_train)``
  per capacity group over that group's surviving cohort (gathered into a
  bucketed fixed-size tensor so jit sees a handful of shapes per group),
  and the selection/deadline mask is folded into the FedAvg weights —
  all groups aggregate in a single ``fedavg_masked`` over concatenated
  per-group stacks and weights.  Small-capacity cohorts train their own
  few steps per epoch instead of the largest group's.
- ``engine="loop"``: the reference per-client Python loop, kept for
  parity testing (see tests/test_engine_parity.py).  It trains each
  client at its own group's capacity, so the two engines stay
  numerically equivalent sample-for-sample.

Both engines draw per-client training randomness from the same
``fold_in(round, client)`` schedule, and both treat an **empty round**
(no client survives selection + deadline — e.g. every evaluation below
``E_tau``) as a no-op broadcast: the global model is unchanged, exactly.
Per-group empty cohorts are skipped the same way — a group never pads
from an empty cohort.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select)
from repro.data.synthetic import make_dataset, train_test_split
from repro.fl.aggregation import fedavg, fedavg_masked
from repro.fl.client import (dataset_loss, dataset_loss_packed,
                             evaluate_accuracy, local_train,
                             local_train_batch)
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig
from repro.fl.partition import (PartitionConfig, partition, stack_clients,
                                steps_per_epoch)
from repro.fl.timing import TimingConfig, completes_before_deadline, \
    training_time_s
from repro.models.cnn import init_cnn

ENGINES = ("batched", "loop")


@dataclass
class FLSimConfig:
    scheme: str = "dcs"                  # dcs | ccs-fuzzy | random
    engine: str = "batched"              # batched (vmapped) | loop (ref)
    n_rounds: int = 20
    n_clients_central: int = 5           # CCS/random pick (Table 3)
    comm_range_m: float = 200.0
    top_m: int = 2                       # per 200 m area (Table 3)
    e_tau: float = 30.0
    local_epochs: int = 2                # paper: 30; scaled for CPU budget
    batch_size: int = 20
    lr: float = 0.05
    prox_mu: float = 0.0                 # >0 enables FedProx
    deadline_s: float = 60.0             # see fl/timing.py docstring
    model_bytes: float = 5.2e6
    state_bytes: float = 100.0
    eval_bytes: float = 30.0
    state_interval_s: float = 1.0
    slowdown_range: tuple = (1.0, 4.0)   # C_i heterogeneity
    probe_samples: int = 256             # Eq. 7 subsample (paper: all
                                         # samples; ranking-equivalent)
    samples_per_class: int = 6600        # source pool size (>= per-class
                                         # demand of the no-dup partition)
    uniform_capacity: bool = False       # True: single max-cap group (the
                                         # pre-grouping layout; benchmark
                                         # baseline only)
    seed: int = 0
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    mobility: MobilityConfig = field(default_factory=MobilityConfig)
    network: NetworkConfig = field(default_factory=NetworkConfig)


class FLSimulation:
    def __init__(self, cfg: FLSimConfig,
                 evaluator: Optional[FuzzyEvaluator] = None):
        if cfg.engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}: "
                             f"{cfg.engine!r}")
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        images, labels = make_dataset(cfg.samples_per_class, seed=cfg.seed)
        (tr_i, tr_l), (te_i, te_l) = train_test_split(images, labels,
                                                      seed=cfg.seed)
        self.test_images = jnp.asarray(te_i)
        self.test_labels = jnp.asarray(te_l)

        parts = partition(tr_i, tr_l, cfg.partition)
        self.n = cfg.partition.n_clients
        self.groups = stack_clients(parts, batch_size=cfg.batch_size,
                                    uniform=cfg.uniform_capacity)
        self.cap = max(g.cap for g in self.groups)
        self._group_steps = [steps_per_epoch(g.cap, cfg.batch_size)
                             for g in self.groups]
        # global (C,) validity + client -> (group, group-local row) map
        self.n_valid = np.zeros(self.n, np.int32)
        self._slot = np.zeros((self.n, 2), np.int64)
        for gi, g in enumerate(self.groups):
            self.n_valid[g.client_ids] = g.n_valid
            self._slot[g.client_ids, 0] = gi
            self._slot[g.client_ids, 1] = np.arange(g.size)
        # each engine keeps only the copy it reads, the dataset is the
        # memory bill: host arrays back the batched engine's cohort
        # gather + probe packing, device arrays feed the loop engine
        if cfg.engine == "batched":
            self._build_packed_probe()
        else:
            self.groups = [dataclasses.replace(g,
                                               images=jnp.asarray(g.images),
                                               labels=jnp.asarray(g.labels))
                           for g in self.groups]

        self.slowdown = rng.uniform(*cfg.slowdown_range, self.n)
        self.network = CellularNetwork(cfg.network)
        # quality proxy for the 'extreme' placement: big data + fast compute
        quality = (self.n_valid / self.n_valid.max()
                   + 1.0 / self.slowdown)
        self.mobility = FreewayMobility(
            cfg.mobility, quality_rank=np.argsort(-quality))
        self.evaluator = evaluator or FuzzyEvaluator(
            FuzzyEvaluatorConfig(e_tau=cfg.e_tau))
        self.params = init_cnn(jax.random.PRNGKey(cfg.seed), CNN_CFG)
        self.key = jax.random.PRNGKey(cfg.seed + 1)       # selection draws
        self.train_key = jax.random.PRNGKey(cfg.seed + 2)  # fold_in schedule
        self.last_mask: Optional[np.ndarray] = None        # set per round

    # ------------------------------------------------------------------
    _PROBE_BATCH = 128

    def _build_packed_probe(self) -> None:
        """Pack every client's valid probe samples into one flat tensor.

        Client membership is static across rounds (the partition never
        changes), so the packing is computed once; each round's probe is
        then a single fused forward pass with zero padding-row FLOPs.
        Clients are packed in global-id order regardless of their
        capacity group."""
        probe = min(self.cfg.probe_samples, self.cap)
        take = np.minimum(self.n_valid, probe).astype(np.int64)
        ims, lbs = [], []
        for i in range(self.n):
            gi, li = self._slot[i]
            g = self.groups[gi]
            ims.append(g.images[li, :take[i]])
            lbs.append(g.labels[li, :take[i]])
        flat_im = np.concatenate(ims)
        flat_lb = np.concatenate(lbs)
        seg = np.repeat(np.arange(self.n), take)
        pad = (-len(seg)) % self._PROBE_BATCH
        if pad:
            flat_im = np.concatenate(
                [flat_im, np.zeros((pad,) + flat_im.shape[1:],
                                   flat_im.dtype)])
            flat_lb = np.concatenate([flat_lb,
                                      np.zeros(pad, flat_lb.dtype)])
            seg = np.concatenate([seg, np.full(pad, self.n)])
        self._probe_images = jnp.asarray(flat_im)
        self._probe_labels = jnp.asarray(flat_lb)
        self._probe_seg = jnp.asarray(seg.astype(np.int32))
        self._probe_counts = jnp.asarray(take.astype(np.int32))

    def _round_keys(self, rnd: int) -> jax.Array:
        """Per-(round, client) PRNG keys — engine-independent, so the loop
        and batched engines train every client with identical randomness."""
        rk = jax.random.fold_in(self.train_key, rnd)
        return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            rk, jnp.arange(self.n))

    def _features(self, pos: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        sq = self.n_valid / max(self.n_valid.max(), 1)
        ta_raw = self.network.predicted_throughput(pos)
        ta = ta_raw / max(ta_raw.max(), 1e-9)
        cc_raw = 1.0 / self.slowdown
        cc = cc_raw / cc_raw.max()
        probe = min(cfg.probe_samples, self.cap)
        if cfg.engine == "batched":
            lf_raw = np.asarray(dataset_loss_packed(
                self.params, self._probe_images, self._probe_labels,
                self._probe_seg, self._probe_counts, n_clients=self.n,
                batch=self._PROBE_BATCH))
        else:
            lf_raw = np.empty(self.n)
            for i in range(self.n):
                gi, li = self._slot[i]
                g = self.groups[gi]
                p = min(probe, g.cap)
                lf_raw[i] = float(dataset_loss(
                    self.params, g.images[li, :p], g.labels[li, :p],
                    jnp.int32(min(int(self.n_valid[i]), p)), batch=128))
        lf = lf_raw / max(lf_raw.max(), 1e-9)
        return np.stack([sq, ta, cc, lf], axis=1).astype(np.float32)

    def _select(self, pos: np.ndarray, evals: jnp.ndarray) -> np.ndarray:
        cfg = self.cfg
        if cfg.scheme == "dcs":
            mask = dcs_select(jnp.asarray(pos), evals,
                              comm_range=cfg.comm_range_m, top_m=cfg.top_m,
                              e_tau=cfg.e_tau)
        elif cfg.scheme == "ccs-fuzzy":
            mask = ccs_fuzzy_select(evals, cfg.n_clients_central)
        elif cfg.scheme == "random":
            self.key, sub = jax.random.split(self.key)
            mask = ccs_random_select(sub, self.n, cfg.n_clients_central)
        else:
            raise ValueError(cfg.scheme)
        return np.asarray(mask)

    def _comm_accounting(self, n_selected: int) -> Dict[str, float]:
        """Per-round communication (bytes and time) per §4.2 / Fig. 9."""
        cfg = self.cfg
        msgs = self.n * cfg.deadline_s / cfg.state_interval_s
        up_bytes = n_selected * cfg.model_bytes
        if cfg.scheme in ("ccs-fuzzy",):
            state_b = msgs * cfg.eval_bytes
            state_t = msgs * 0.2
        elif cfg.scheme == "random":
            state_b = msgs * cfg.state_bytes
            state_t = msgs * 0.2
        else:                                   # dcs: DSRC evaluations only
            state_b = msgs * cfg.eval_bytes
            state_t = msgs * 0.04
        return {"state_bytes": state_b, "upload_bytes": up_bytes,
                "state_time_s": state_t}

    # -- local training + aggregation (steps 5-7) ----------------------
    def _train_loop(self, survivors: np.ndarray,
                    keys: jax.Array) -> None:
        """Reference path: per-client jitted local_train calls + list
        FedAvg over the survivors.  An empty round is a no-op broadcast.
        Each client trains at its own capacity group's cap/steps, so the
        per-client math matches the grouped batched engine exactly."""
        cfg = self.cfg
        new_models, weights = [], []
        for i in np.where(survivors)[0]:
            gi, li = self._slot[i]
            g = self.groups[gi]
            p_i, _ = local_train(
                self.params, g.images[li], g.labels[li],
                jnp.int32(self.n_valid[i]), keys[i], epochs=cfg.local_epochs,
                batch_size=cfg.batch_size,
                steps_per_epoch=self._group_steps[gi], lr=cfg.lr,
                prox_mu=cfg.prox_mu)
            new_models.append(p_i)
            weights.append(float(self.n_valid[i]))
        if new_models:                           # Eq. 2
            self.params = fedavg(new_models, weights)

    @staticmethod
    def _bucket(k: int) -> int:
        """Cohort tensor size for k survivors: next multiple of 2, min 2 —
        jit compiles a handful of shapes no matter how the per-round
        selection count fluctuates.  The floor matters for capacity
        groups: a Table-3 big-group cohort of 1-2 must not train (and
        compile) 4 padded 4500-sample slots."""
        return max(2, k + (k % 2))

    def warmup(self, buckets=None) -> None:
        """Pre-compile the batched trainer for the given cohort bucket
        sizes in every capacity group (the jit cache persists across
        rounds).  The default covers small cohorts plus the
        central-selection budget, clipped to each group's size; a cohort
        that lands in an uncovered bucket still works — it just compiles
        on first use.  No-op for the loop engine."""
        if self.cfg.engine != "batched":
            return
        cfg = self.cfg
        if buckets is None:
            buckets = sorted({2, 4, 6, 8,
                              self._bucket(min(cfg.n_clients_central,
                                               self.n))})
        keys = self._round_keys(0)
        for gi, g in enumerate(self.groups):
            for b in sorted({min(b, self._bucket(g.size)) for b in buckets}):
                idx = np.zeros(b, np.int64)
                local_train_batch(
                    self.params, jnp.asarray(g.images[idx]),
                    jnp.asarray(g.labels[idx]),
                    jnp.asarray(g.n_valid[idx]),
                    keys[jnp.asarray(g.client_ids[idx])],
                    epochs=cfg.local_epochs, batch_size=cfg.batch_size,
                    steps_per_epoch=self._group_steps[gi], lr=cfg.lr,
                    prox_mu=cfg.prox_mu)

    def _train_batched(self, survivors: np.ndarray,
                       keys: jax.Array) -> None:
        """One vmap(local_train) per capacity group over that group's
        surviving cohort; the mask enters Eq. 2 only through the FedAvg
        weights — cohort padding rows train like everyone else and
        aggregate at weight zero.  Stragglers are dropped at the gather
        (their update is discarded either way; at IoV scale their local
        SGD FLOPs are not).  Groups with an empty cohort are skipped —
        never padded from a nonexistent ``cohort[0]`` — and a fully empty
        round leaves the global model untouched (no-op broadcast)."""
        cfg = self.cfg
        if not survivors.any():
            return                               # empty round: no-op
        stacks, weights = [], []
        for gi, g in enumerate(self.groups):
            cohort = np.where(survivors[g.client_ids])[0]  # group-local
            k = len(cohort)
            if k == 0:
                continue                         # empty cohort: skip group
            bucket = self._bucket(k)
            idx = np.concatenate([cohort, np.full(bucket - k, cohort[0])])
            stacked, _ = local_train_batch(
                self.params, jnp.asarray(g.images[idx]),
                jnp.asarray(g.labels[idx]), jnp.asarray(g.n_valid[idx]),
                keys[jnp.asarray(g.client_ids[idx])],
                epochs=cfg.local_epochs, batch_size=cfg.batch_size,
                steps_per_epoch=self._group_steps[gi], lr=cfg.lr,
                prox_mu=cfg.prox_mu)
            w = g.n_valid[idx].astype(np.float32)
            w[k:] = 0.0                          # padding duplicates drop out
            stacks.append(stacked)
            weights.append(w)
        merged = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *stacks)
        self.params = fedavg_masked(
            merged, jnp.asarray(np.concatenate(weights)))  # Eq. 2

    # ------------------------------------------------------------------
    def run_round(self, rnd: int) -> Dict[str, float]:
        cfg = self.cfg
        t = rnd * cfg.deadline_s
        pos = self.mobility.positions(t)
        feats = self._features(pos)
        evals = self.evaluator.evaluate(jnp.asarray(feats))
        mask = self._select(pos, evals)
        self.last_mask = mask
        sel = np.where(mask > 0)[0]

        # deadline filter (Eq. 6)
        tcfg = TimingConfig(cfg.local_epochs, cfg.batch_size,
                            deadline_s=cfg.deadline_s)
        train_t = training_time_s(tcfg, self.slowdown, self.n_valid)
        upload_t = self.network.upload_time_s(pos, cfg.model_bytes)
        ok = completes_before_deadline(tcfg, train_t, upload_t)
        selected = mask > 0
        survivors = selected & ok
        n_straggler = int((selected & ~ok).sum())

        # local training (Eq. 1) + aggregation (Eq. 2)
        keys = self._round_keys(rnd)
        if cfg.engine == "batched":
            self._train_batched(survivors, keys)
        else:
            self._train_loop(survivors, keys)

        acc = evaluate_accuracy(self.params, self.test_images,
                                self.test_labels, batch=256)
        row = {"round": rnd, "accuracy": acc, "n_selected": len(sel),
               "n_aggregated": int(survivors.sum()),
               "n_straggler": n_straggler,
               "mean_eval_selected": float(
                   evals[sel].mean()) if len(sel) else 0.0}
        row.update(self._comm_accounting(len(sel)))
        return row

    def run(self, n_rounds: Optional[int] = None) -> List[Dict[str, float]]:
        n = n_rounds or self.cfg.n_rounds
        return [self.run_round(r) for r in range(n)]
