"""The federated round engine (paper Alg. 1 + §6 simulator).

One ``FLSimulation`` couples: the synthetic non-iid dataset partition,
freeway mobility, the cellular/CWND network model, the Eq. 6 timing model,
the fuzzy evaluator and one of the three selection schemes.  Each round:

  1. broadcast: every participant receives the global model;
  2. probe: every participant computes Eq. 7 (loss of the *global* model
     over its local data, no update);
  3. evaluate: fuzzy evaluation from (SQ, TA, CC, LF), locally;
  4. select: dcs (neighbour election) / ccs-fuzzy (server top-n) /
     random (server uniform);
  5. train: selected clients run Eq. 1 local SGD;
  6. deadline: models whose train+upload time exceeds the deadline are
     discarded (stragglers);
  7. aggregate: FedAvg (Eq. 2) over the survivors;
  8. account: state-maintenance vs evaluation-exchange communication.

Client datasets are stored **capacity-grouped**: ``stack_clients``
buckets clients by quantity-rounded-to-batches capacity and returns one
fixed-shape ``ClientGroup`` per bucket (Table-3 full profile: a 4500-cap
group of 12 and a 60-cap group of 18).  Two engines implement steps
2/5/7 over these groups:

- ``engine="batched"`` (default): the Eq. 7 probe is one fused forward
  pass over a packed concatenation of every client's valid probe samples
  (padding rows cost nothing), local SGD is one ``vmap(local_train)``
  per capacity group over that group's surviving cohort (gathered into a
  bucketed fixed-size tensor so jit sees a handful of shapes per group),
  and the selection/deadline mask is folded into the FedAvg weights —
  all groups aggregate in a single ``fedavg_masked`` over concatenated
  per-group stacks and weights.  Small-capacity cohorts train their own
  few steps per epoch instead of the largest group's.
- ``engine="loop"``: the reference per-client Python loop, kept for
  parity testing (see tests/test_engine_parity.py).  It trains each
  client at its own group's capacity, so the two engines stay
  numerically equivalent sample-for-sample.

Both engines draw per-client training randomness from the same
``fold_in(round, client)`` schedule, and both treat an **empty round**
(no client survives selection + deadline — e.g. every evaluation below
``E_tau``) as a no-op broadcast: the global model is unchanged, exactly.
Per-group empty cohorts are skipped the same way — a group never pads
from an empty cohort.

Steps 1-4 and 6 (probe -> fuzzy evaluate -> select -> deadline) are the
**staged pure pipeline** of ``fl/pipeline.py``: one jitted
``selection_prefix`` with no host round-trips, shared by both engines —
``FLSimulation`` is a thin stateful wrapper that holds the statics /
PRNG bases and crosses the survivor mask to the host exactly once, at
the cohort gather.  The sweep harness (``repro.launch.sweep``) drives
the same prefix vmapped across seeds.

Constructed inside an active ``logical_sharding`` context whose mesh has
a live ``clients`` axis (the launchers' ``--mesh clients=K``), the
simulation partitions the in-round client axis over that mesh: the
prefix runs as ``selection_prefix_sharded`` (same masks bit-for-bit),
the probe packs one sample region per shard, and the batched engine
trains each capacity group through the shard_map'd grouped trainer with
a cross-device psum'd FedAvg (``train_groups_sharded``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.core.overhead import (accumulated_time_s, IoVParams,
                                 model_upload_bytes,
                                 state_maintenance_bytes)
from repro.data.synthetic import make_dataset, train_test_split
from repro.fl import pipeline
from repro.fl.aggregation import fedavg
from repro.fl.client import (evaluate_accuracy_async, local_train,
                             local_train_batch_donated)
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import NetworkConfig
from repro.fl.partition import (PartitionConfig, partition,
                                shard_client_range, stack_clients,
                                steps_per_epoch)
from repro.fl.runconfig import ENGINES, RunConfig, resolve_run
from repro.fl.schemes import get_scheme
from repro.launch import faults
from repro.models.cnn import init_cnn
from repro.sharding.api import CLIENT_AXIS, mesh_is_multihost


def build_round_checkpointer(run_cfg: RunConfig, checkpointer=None):
    """The driver-facing checkpoint seam (ISSUE 10): an explicit
    ``RoundCheckpointer`` wins; otherwise one is built from the run
    config's ``checkpoint_dir``/``checkpoint_every``; ``None`` disables
    checkpointing entirely."""
    if checkpointer is not None:
        return checkpointer
    if run_cfg.checkpoint_dir:
        from repro.train.checkpoint import RoundCheckpointer
        return RoundCheckpointer(run_cfg.checkpoint_dir,
                                 every=run_cfg.checkpoint_every)
    return None


def resume_rows(driver, ckpt, resume: bool):
    """Restore ``driver`` (an ``FLSimulation`` or ``EventDrivenServer``)
    from the latest good snapshot -> ``(rows_so_far, start_round)``.

    Corrupt snapshots were already skipped (with a warning) inside
    ``latest_good``; no snapshot at all means a fresh start — resume is
    idempotent and safe to pass unconditionally."""
    if not resume or ckpt is None:
        return [], 0
    got = ckpt.latest_good()
    if got is None:
        return [], 0
    rnd, state, extra = got
    driver.restore_state(state, extra)
    return [dict(r) for r in extra.get("rows", [])], rnd + 1


def checkpoint_round(driver, ckpt, rnd: int, rows, *,
                     lead: bool = True) -> None:
    """Snapshot the end-of-round state when due (lead process only),
    then announce the fault-injection events the chaos suite keys on."""
    if ckpt is not None and lead and ckpt.due(rnd):
        ckpt.save_round(rnd, driver.capture_state(),
                        extra={"rows": rows, "next_round": rnd + 1})
        faults.fire("checkpoint-saved", round=rnd)
    faults.fire("round-done", round=rnd)


@dataclass
class FLSimConfig:
    scheme: str = "dcs"                  # any registered scheme
                                         # (fl/schemes.py; builtins:
                                         # dcs | ccs-fuzzy | random)
    # deprecated (one release): engine/fused_probe/overlap_rounds moved
    # to RunConfig — a non-None value here still works but warns and is
    # folded into the run config (repro.fl.runconfig.resolve_run)
    engine: Optional[str] = None
    n_rounds: int = 20
    n_clients_central: int = 5           # CCS/random pick (Table 3)
    comm_range_m: float = 200.0
    top_m: int = 2                       # per 200 m area (Table 3)
    e_tau: float = 30.0
    local_epochs: int = 2                # paper: 30; scaled for CPU budget
    batch_size: int = 20
    lr: float = 0.05
    prox_mu: float = 0.0                 # >0 enables FedProx
    deadline_s: float = 60.0             # see fl/timing.py docstring
    model_bytes: float = 5.2e6
    state_bytes: float = 100.0
    eval_bytes: float = 30.0
    state_interval_s: float = 1.0
    slowdown_range: tuple = (1.0, 4.0)   # C_i heterogeneity
    probe_samples: int = 256             # Eq. 7 subsample (paper: all
                                         # samples; ranking-equivalent)
    samples_per_class: int = 6600        # source pool size (>= per-class
                                         # demand of the no-dup partition)
    uniform_capacity: bool = False       # True: single max-cap group (the
                                         # pre-grouping layout; benchmark
                                         # baseline only)
    fused_probe: Optional[bool] = None   # deprecated: RunConfig.fused_probe
    overlap_rounds: Optional[bool] = None  # deprecated:
                                         # RunConfig.overlap_rounds
    seed: int = 0
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    mobility: MobilityConfig = field(default_factory=MobilityConfig)
    network: NetworkConfig = field(default_factory=NetworkConfig)


class FLSimulation:
    def __init__(self, cfg: FLSimConfig,
                 evaluator: Optional[FuzzyEvaluator] = None,
                 run: Optional[RunConfig] = None):
        # the execution profile: engine / fused probe / overlap / async
        # axis — one RunConfig shared by all three entry points; the
        # deprecated FLSimConfig kwargs fold in behind a warning
        self.run_cfg = resolve_run(cfg, run)
        get_scheme(cfg.scheme)               # unknown schemes raise here
        self.cfg = cfg
        # a live ("clients",) mesh axis partitions the in-round client
        # axis (sharded prefix + grouped trainer); captured at
        # construction so the probe packs one sample region per shard
        self.client_mesh = pipeline.active_client_mesh()
        self.n_shards = pipeline.mesh_client_shards(self.client_mesh)
        # a mesh spanning several jax processes (launch --multihost, or a
        # real multi-host TPU slice): every process runs this same driver
        # SPMD; per-client statics materialize addressable shards only,
        # host-consumed arrays (params, round state) stay replicated
        self.multihost = mesh_is_multihost(self.client_mesh)
        if self.multihost and self.run_cfg.engine != "batched":
            raise ValueError("multi-host meshes require engine='batched'")
        if self.multihost and self.run_cfg.server == "event":
            raise ValueError("the event-driven server does not support "
                             "multi-host meshes yet")
        rng = np.random.default_rng(cfg.seed)
        images, labels = make_dataset(cfg.samples_per_class, seed=cfg.seed)
        (tr_i, tr_l), (te_i, te_l) = train_test_split(images, labels,
                                                      seed=cfg.seed)
        self.test_images = jnp.asarray(te_i)
        self.test_labels = jnp.asarray(te_l)

        parts = partition(tr_i, tr_l, cfg.partition)
        self.n = cfg.partition.n_clients
        self.groups = stack_clients(parts, batch_size=cfg.batch_size,
                                    uniform=cfg.uniform_capacity)
        self.cap = max(g.cap for g in self.groups)
        self._group_steps = [steps_per_epoch(g.cap, cfg.batch_size)
                             for g in self.groups]
        # global (C,) validity + client -> (group, group-local row) map
        self.n_valid = np.zeros(self.n, np.int32)
        self._slot = np.zeros((self.n, 2), np.int64)
        for gi, g in enumerate(self.groups):
            self.n_valid[g.client_ids] = g.n_valid
            self._slot[g.client_ids, 0] = gi
            self._slot[g.client_ids, 1] = np.arange(g.size)
        # the packed Eq. 7 probe feeds the staged selection prefix in
        # BOTH engines (it is the pipeline's loss-feature input)
        self._build_packed_probe()
        # the full dataset is the memory bill, and each engine keeps only
        # the copy it reads: host arrays back the batched engine's cohort
        # gather, device arrays feed the loop engine's per-client calls
        if self.run_cfg.engine != "batched":
            self.groups = [dataclasses.replace(g,
                                               images=jnp.asarray(g.images),
                                               labels=jnp.asarray(g.labels))
                           for g in self.groups]

        self.slowdown = rng.uniform(*cfg.slowdown_range, self.n)
        # quality proxy for the 'extreme' placement: big data + fast compute
        quality = (self.n_valid / self.n_valid.max()
                   + 1.0 / self.slowdown)
        self.mobility = FreewayMobility(
            cfg.mobility, quality_rank=np.argsort(-quality))
        self.evaluator = evaluator or FuzzyEvaluator(
            FuzzyEvaluatorConfig(e_tau=cfg.e_tau))
        self.params = init_cnn(jax.random.PRNGKey(cfg.seed), CNN_CFG)
        self.key = jax.random.PRNGKey(cfg.seed + 1)       # selection draws
        self.train_key = jax.random.PRNGKey(cfg.seed + 2)  # fold_in schedule
        # network randomness base (replaces the stateful numpy generator
        # inside the staged prefix; folded per round, split per use).
        # Folding in the simulation seed keeps NetworkConfig — a
        # jit-static — shareable across a sweep's seed axis while every
        # seed still sees its own channel realizations.
        self.net_key = jax.random.fold_in(
            jax.random.PRNGKey(cfg.network.seed + 53), cfg.seed)
        if self.multihost:
            # host-numpy leaves: every process feeds the multi-process
            # jits identical replicated inputs (committed single-device
            # arrays would not be globally addressable)
            self.params = jax.device_get(self.params)
            self.key = np.asarray(self.key)
            self.train_key = np.asarray(self.train_key)
            self.net_key = np.asarray(self.net_key)
        self.last_mask: Optional[np.ndarray] = None        # set per round
        # lifetime per-client participation counts (selection mask hits);
        # checkpointed so budget/fairness schemes survive preemption
        self.participation = np.zeros(self.n, np.int64)
        self.statics = self._build_statics()
        self.stage_cfg = self._build_stage_cfg()

    # -- staged-pipeline state -----------------------------------------
    def _build_statics(self) -> pipeline.RoundStatics:
        """The arrays the pure stages read — fixed for the simulation's
        lifetime (the partition, placement and hardware mix are static)."""
        f32 = jnp.float32
        ecfg = self.evaluator.cfg
        if self.multihost:
            # replicated host-numpy leaves (tiny (N,) vectors) except the
            # probe tensors, which _build_packed_probe materialized as
            # global client-sharded arrays with addressable shards only
            f32 = np.float32
            return pipeline.RoundStatics(
                x0=np.asarray(self.mobility.x0, f32),
                speeds=np.asarray(self.mobility.speeds, f32),
                jitter_phase=np.asarray(self.mobility._jitter_phase, f32),
                slowdown=np.asarray(self.slowdown, f32),
                n_valid=np.asarray(self.n_valid, f32),
                probe_images=self._probe_images,
                probe_labels=self._probe_labels,
                probe_seg=self._probe_seg,
                probe_counts=np.asarray(self._probe_counts),
                means=np.asarray(ecfg.means, f32),
                sigmas=np.asarray(ecfg.sigmas, f32),
                level_centers=np.asarray(self.evaluator.level_centers, f32))
        return pipeline.RoundStatics(
            x0=jnp.asarray(self.mobility.x0, f32),
            speeds=jnp.asarray(self.mobility.speeds, f32),
            jitter_phase=jnp.asarray(self.mobility._jitter_phase, f32),
            slowdown=jnp.asarray(self.slowdown, f32),
            n_valid=jnp.asarray(self.n_valid, f32),
            probe_images=self._probe_images,
            probe_labels=self._probe_labels,
            probe_seg=self._probe_seg,
            probe_counts=self._probe_counts,
            means=jnp.asarray(ecfg.means, f32),
            sigmas=jnp.asarray(ecfg.sigmas, f32),
            level_centers=jnp.asarray(self.evaluator.level_centers, f32))

    def _build_stage_cfg(self) -> pipeline.StageConfig:
        return self.run_cfg.to_stage_config(self.cfg, n_clients=self.n,
                                        probe_batch=self._PROBE_BATCH)

    # ------------------------------------------------------------------
    _PROBE_BATCH = 128

    def _build_packed_probe(self) -> None:
        """Pack every client's valid probe samples into one flat tensor,
        client-aligned and (when a client mesh is active) shard-regioned.

        Client membership is static across rounds (the partition never
        changes), so the packing is computed once; each round's probe is
        then a single fused forward pass.  Each client's samples are
        padded to a whole number of probe batches (sentinel rows carry
        ``seg == n``, the overflow lane), so a batch never spans two
        clients; clients are then grouped into ``n_shards`` equal-length
        contiguous regions — one per mesh shard, padded to the longest
        with sentinel batches — which makes the sample axis exactly
        partitionable over the client mesh.  Sentinel rows only ever add
        exact zeros to real clients' Eq. 7 loss lanes, so the per-client
        losses are bitwise identical for every shard count (the
        sharded-vs-single-device mask parity rests on this).  The
        alignment costs probe FLOPs — up to ``_PROBE_BATCH - 1`` sentinel
        rows per client even unsharded, vs the pre-mesh tight pack — a
        deliberate trade: the probe is one forward pass per round and
        the alignment is what keeps masks reproducible across meshes.

        ``fused_probe=True`` packs TIGHT instead: no per-client batch
        alignment, so a 45-sample Table-3 client contributes 45 probe
        rows, not 128 — on quantity-skewed fleets this halves (or
        better) the probe FLOPs, which is most of the fused fast path's
        measured CPU win (benchmarks ``prefix_fusion``).  Per-client
        losses then sum the same sample losses in a different batch
        grouping, so they can differ from the aligned pack in the last
        ulp; the selection masks are pinned bit-identical to the
        default path in tests/test_probe_fuzzy.py."""
        probe = min(self.cfg.probe_samples, self.cap)
        take = np.minimum(self.n_valid, probe).astype(np.int64)
        batch = self._PROBE_BATCH
        align = 1 if self.run_cfg.fused_probe else batch
        im_shape = self.groups[0].images.shape[2:]
        im_dtype = self.groups[0].images.dtype
        lb_dtype = self.groups[0].labels.dtype

        def shard_range(d):
            return shard_client_range(self.n, self.n_shards, d)

        # the common region length is agreed from counts alone — every
        # process computes it for ALL shards without touching sample data
        aligned = take + (-take) % align
        length = max(batch, max(
            int(sum(aligned[i] for i in shard_range(d)) or 0)
            for d in range(self.n_shards)))

        def build_region(d):
            """Shard ``d``'s probe region, padded to ``length`` with
            sentinel rows (seg == n: the overflow loss lane)."""
            ims, lbs, segs = [], [], []
            for i in shard_range(d):
                gi, li = self._slot[i]
                g = self.groups[gi]
                t = int(take[i])
                ims.append(g.images[li, :t])
                lbs.append(g.labels[li, :t])
                segs.append(np.full(t, i))
                pad = (-t) % align
                if pad:                      # align the client to batches
                    ims.append(np.zeros((pad,) + im_shape, im_dtype))
                    lbs.append(np.zeros(pad, lb_dtype))
                    segs.append(np.full(pad, self.n))
            used = int(sum(aligned[i] for i in shard_range(d)) or 0)
            pad = length - used
            ims.append(np.zeros((pad,) + im_shape, im_dtype))
            lbs.append(np.zeros(pad, lb_dtype))
            segs.append(np.full(pad, self.n))
            return (np.concatenate(ims), np.concatenate(lbs),
                    np.concatenate(segs).astype(np.int32))

        if not self.multihost:
            regions = [build_region(d) for d in range(self.n_shards)]
            self._probe_images = jnp.asarray(
                np.concatenate([r[0] for r in regions]))
            self._probe_labels = jnp.asarray(
                np.concatenate([r[1] for r in regions]))
            self._probe_seg = jnp.asarray(
                np.concatenate([r[2] for r in regions]))
        else:
            # per-host loading: each process builds ONLY the regions its
            # devices own and assembles global client-sharded arrays —
            # the (S, 28, 28, 1) probe stack never fully materializes on
            # any single host
            from jax.sharding import NamedSharding, PartitionSpec
            mesh = self.client_mesh
            cache: Dict[int, tuple] = {}

            def region(d):
                if d not in cache:
                    cache[d] = build_region(d)
                return cache[d]

            def globalize(col, extra_dims, dtype):
                shape = (self.n_shards * length,) + extra_dims
                sh = NamedSharding(
                    mesh, PartitionSpec(CLIENT_AXIS,
                                        *([None] * len(extra_dims))))

                def cb(index):
                    start = index[0].start or 0
                    return np.asarray(region(start // length)[col],
                                      dtype=dtype)

                return jax.make_array_from_callback(shape, sh, cb)

            self._probe_images = globalize(0, im_shape, im_dtype)
            self._probe_labels = globalize(1, (), lb_dtype)
            self._probe_seg = globalize(2, (), np.int32)
            cache.clear()
        self._probe_counts = jnp.asarray(take.astype(np.int32)) \
            if not self.multihost else take.astype(np.int32)

    def _round_keys(self, rnd: int) -> jax.Array:
        """Per-(round, client) PRNG keys — engine-independent, so the loop
        and batched engines train every client with identical randomness."""
        rk = jax.random.fold_in(self.train_key, rnd)
        return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            rk, jnp.arange(self.n))

    def selection_state(self, rnd: int, *,
                        elect: Optional[str] = None) -> Dict[str, jax.Array]:
        """Run the staged selection prefix (probe -> evaluate -> select ->
        deadline) for round ``rnd`` as one jitted call.  Deterministic in
        ``(params, rnd)``: the same round can be queried repeatedly.

        ``elect`` overrides the stage config's election seam for this
        call — the overflow fallback re-runs a round with
        ``elect="gather"`` (see ``resolve_elect_overflow``).

        The evaluator's membership parameters are re-read every call, so
        a post-construction ``FuzzyEvaluator.calibrate()`` takes effect
        on the next round exactly as in the host-driven engine.  (The
        sweep's vmapped path stacks statics once up front and therefore
        pins calibration at stacking time.)"""
        ecfg = self.evaluator.cfg
        arr = np.asarray if self.multihost \
            else (lambda a, d: jnp.asarray(a, d))
        st = dataclasses.replace(
            self.statics,
            means=arr(ecfg.means, np.float32),
            sigmas=arr(ecfg.sigmas, np.float32))
        cfg = self.stage_cfg
        if elect is not None and elect != cfg.elect:
            cfg = dataclasses.replace(cfg, elect=elect)
        rnd_in = np.int32(rnd) if self.multihost else jnp.int32(rnd)
        if self.client_mesh is not None:
            return pipeline.selection_prefix_sharded(
                st, self.params, rnd_in, self.key,
                self.net_key, cfg=cfg, mesh=self.client_mesh)
        return pipeline.selection_prefix(
            st, self.params, rnd_in, self.key,
            self.net_key, cfg=cfg)

    def resolve_elect_overflow(self, rnd: int, host: Dict) -> Dict:
        """The windowed election's parity escape hatch: when round
        ``rnd``'s prefix raised ``elect_overflow`` (a fixed window/halo
        buffer could not hold every dense comparison), re-run the prefix
        with the gather election and use that state instead.  The prefix
        is pure in ``(params, rnd)``, so the re-run sees identical
        inputs — the returned masks are exactly the dense election's."""
        if int(np.max(host.get("elect_overflow", 0))) == 0:
            return host
        return jax.device_get(self.selection_state(rnd, elect="gather"))

    def _comm_accounting(self, n_selected: int) -> Dict[str, float]:
        """Per-round communication (bytes and time) per §4.2 / Fig. 9,
        routed through ``core/overhead.py`` so the simulator and the
        Fig. 2 / Fig. 9 analytics report consistent numbers — including
        the DUPLEX_FACTOR on state traffic and the IoVParams per-message
        latencies (cloud vs DSRC).  The accumulated-time model key comes
        from the scheme registry: ``"cfl"`` schemes maintain classical
        full state, the others exchange evaluations (cloud vs DSRC)."""
        cfg = self.cfg
        key = get_scheme(cfg.scheme).overhead_key
        state_bytes = (cfg.state_bytes if key == "cfl" else cfg.eval_bytes)
        p = IoVParams(n_participants=self.n, clients_per_round=n_selected,
                      round_period_s=cfg.deadline_s,
                      model_bytes=cfg.model_bytes,
                      state_bytes_cfl=cfg.state_bytes,
                      state_bytes_ccs_fuzzy=cfg.eval_bytes,
                      eval_bytes_dcs=cfg.eval_bytes,
                      uplink_bps_best=cfg.network.best_rate_bps,
                      uplink_bps_worst=cfg.network.worst_rate_bps)
        comm_t = accumulated_time_s(key, cfg.state_interval_s, p)
        upload_t = accumulated_time_s("model-only", cfg.state_interval_s, p)
        return {"state_bytes": state_maintenance_bytes(
                    self.n, state_bytes, cfg.deadline_s,
                    cfg.state_interval_s),
                "upload_bytes": model_upload_bytes(n_selected,
                                                   cfg.model_bytes),
                "state_time_s": comm_t - upload_t,
                "comm_time_s": comm_t}

    # -- local training + aggregation (steps 5-7) ----------------------
    def _train_loop(self, survivors: np.ndarray,
                    keys: jax.Array) -> None:
        """Reference path: per-client jitted local_train calls + list
        FedAvg over the survivors.  An empty round is a no-op broadcast.
        Each client trains at its own capacity group's cap/steps, so the
        per-client math matches the grouped batched engine exactly."""
        cfg = self.cfg
        new_models, weights = [], []
        for i in np.where(survivors)[0]:
            gi, li = self._slot[i]
            g = self.groups[gi]
            p_i, _ = local_train(
                self.params, g.images[li], g.labels[li],
                jnp.int32(self.n_valid[i]), keys[i], epochs=cfg.local_epochs,
                batch_size=cfg.batch_size,
                steps_per_epoch=self._group_steps[gi], lr=cfg.lr,
                prox_mu=cfg.prox_mu)
            new_models.append(p_i)
            weights.append(float(self.n_valid[i]))
        if new_models:                           # Eq. 2
            self.params = fedavg(new_models, weights)

    # cohort bucketing lives with the staged training stage now
    _bucket = staticmethod(pipeline.cohort_bucket)

    def _bucket_n(self, k: int) -> int:
        """Cohort bucket for ``k`` survivors, rounded to a mesh multiple
        when the client axis is sharded (every device gets an equal
        cohort slice)."""
        return pipeline.cohort_bucket_sharded(k, self.n_shards)

    def warmup(self, buckets=None) -> None:
        """Pre-compile the batched trainer for the given cohort bucket
        sizes in every capacity group (the jit cache persists across
        rounds).  The default covers small cohorts plus the
        central-selection budget, clipped to each group's size; a cohort
        that lands in an uncovered bucket still works — it just compiles
        on first use.  No-op for the loop engine."""
        if self.run_cfg.engine != "batched":
            return
        cfg = self.cfg
        if buckets is None:
            buckets = sorted({self._bucket_n(k) for k in
                              (2, 4, 6, 8, min(cfg.n_clients_central,
                                               self.n))})
        keys = self._round_keys(0)
        for gi, g in enumerate(self.groups):
            for b in sorted({min(b, self._bucket_n(g.size))
                             for b in buckets}):
                idx = np.zeros(b, np.int64)
                if self.client_mesh is not None:
                    pipeline.train_group_cohort_sharded(
                        self.params, g, self._group_steps[gi], idx,
                        np.zeros(b, np.float32),
                        keys[jnp.asarray(g.client_ids[idx])],
                        self.client_mesh, epochs=cfg.local_epochs,
                        batch_size=cfg.batch_size, lr=cfg.lr,
                        prox_mu=cfg.prox_mu)
                    continue
                # the donated twin is the jit the round path actually
                # calls (train_groups) — warming the plain wrapper
                # would fill a cache nobody reads; the dummy inputs
                # here are fresh, so donation is safe
                local_train_batch_donated(
                    self.params, jnp.asarray(g.images[idx]),
                    jnp.asarray(g.labels[idx]),
                    jnp.asarray(g.n_valid[idx]),
                    keys[jnp.asarray(g.client_ids[idx])],
                    epochs=cfg.local_epochs, batch_size=cfg.batch_size,
                    steps_per_epoch=self._group_steps[gi], lr=cfg.lr,
                    prox_mu=cfg.prox_mu)

    def _train_batched(self, survivors: np.ndarray,
                       keys: jax.Array) -> None:
        """The staged ``train_groups`` + ``aggregate`` stages: one
        vmap(local_train) per capacity group over that group's surviving
        cohort, the mask folded into the FedAvg weights (Eq. 2).
        Stragglers are dropped at the gather (their update is discarded
        either way; at IoV scale their local SGD FLOPs are not).  An
        empty round (or per-group cohort) is a no-op broadcast.  Under a
        client mesh each device trains its shard of every group's cohort
        and FedAvg finishes with a cross-device psum."""
        cfg = self.cfg
        if self.client_mesh is not None:
            trained = pipeline.train_groups_sharded(
                self.params, self.groups, self._group_steps, survivors,
                keys, self.client_mesh, epochs=cfg.local_epochs,
                batch_size=cfg.batch_size, lr=cfg.lr, prox_mu=cfg.prox_mu)
            self.params = pipeline.aggregate_sharded(self.params, trained)
            return
        trained = pipeline.train_groups(
            self.params, self.groups, self._group_steps, survivors, keys,
            epochs=cfg.local_epochs, batch_size=cfg.batch_size, lr=cfg.lr,
            prox_mu=cfg.prox_mu)
        self.params = pipeline.aggregate(self.params, trained)

    # ------------------------------------------------------------------
    def run_round(self, rnd: int) -> Dict[str, float]:
        """One federated round: the jitted staged prefix (steps 1-4 + 6),
        then the engine's training/aggregation (steps 5 + 7)."""
        return self.finish_round(rnd, self.selection_state(rnd))

    def finish_round(self, rnd: int,
                     state: Dict[str, jax.Array]) -> Dict[str, float]:
        """Complete round ``rnd`` from a selection-prefix output (which
        may come from a seed-vmapped sweep dispatch).  This is the single
        device->host crossing of the round — the survivor mask becomes
        concrete here, at the cohort gather."""
        host = self.resolve_elect_overflow(rnd, jax.device_get(state))
        self._dispatch_training(rnd, host)
        acc, n_test = evaluate_accuracy_async(
            self._eval_params(), self.test_images, self.test_labels,
            batch=256)
        return self._round_row(rnd, host, acc, n_test)

    def _eval_params(self):
        """Params as the accuracy evaluation consumes them: under a
        multi-host mesh the global (replicated) device arrays come back
        to the host first, so the local eval jit sees process-local
        inputs; otherwise the device params pass straight through."""
        return jax.device_get(self.params) if self.multihost \
            else self.params

    def _dispatch_training(self, rnd: int, host: Dict) -> None:
        """Steps 5 + 7 from a host-side prefix state: cohort gather and
        training/aggregation dispatch.  Returns as soon as the work is
        enqueued — ``self.params`` becomes a device future."""
        survivors = np.asarray(host["survivors"])
        self._record_participation(host["mask"])
        keys = self._round_keys(rnd)
        if self.run_cfg.engine == "batched":
            self._train_batched(survivors, keys)
        else:
            self._train_loop(survivors, keys)

    def _record_participation(self, mask) -> None:
        """Track the round's selection mask and bump the lifetime
        participation counters (single bookkeeping point for the sync
        dispatch and the event server's enqueue)."""
        self.last_mask = np.asarray(mask)
        self.participation[self.last_mask > 0] += 1

    # -- preemption safety (ISSUE 10) ----------------------------------
    def capture_state(self) -> Dict:
        """The complete mutable round state, as host arrays: params, all
        PRNG bases, participation counters, the last selection mask and
        the mobility field.  Everything else the rounds read is static
        (rebuilt from ``FLSimConfig`` at construction), so restoring
        this into a freshly constructed simulation reproduces the
        uninterrupted trajectory bit-for-bit.

        The PRNG bases and mobility arrays are constants per config —
        they are captured anyway so ``restore_state`` can *verify* the
        resuming process was constructed from the same config instead of
        trusting the caller."""
        return {
            "params": jax.device_get(self.params),
            "key": np.asarray(self.key),
            "train_key": np.asarray(self.train_key),
            "net_key": np.asarray(self.net_key),
            "participation": np.asarray(self.participation),
            "last_mask": (np.asarray(self.last_mask)
                          if self.last_mask is not None
                          else np.zeros(self.n, np.float32)),
            "mobility": {
                "x0": np.asarray(self.mobility.x0, np.float64),
                "speeds": np.asarray(self.mobility.speeds, np.float64),
                "jitter_phase": np.asarray(self.mobility._jitter_phase,
                                           np.float64)},
        }

    def restore_state(self, state: Dict,
                      extra: Optional[Dict] = None) -> None:
        """Restore a ``capture_state`` snapshot.  Raises ``ValueError``
        when the snapshot demonstrably came from a different
        configuration (fleet size, seeds, mobility field)."""
        part = np.asarray(state["participation"])
        if part.shape != (self.n,):
            raise ValueError(
                f"checkpoint is for a {part.shape[0]}-client fleet; this "
                f"simulation has {self.n} clients")
        for name, cur in (("key", self.key), ("train_key", self.train_key),
                          ("net_key", self.net_key)):
            if not np.array_equal(np.asarray(state[name]), np.asarray(cur)):
                raise ValueError(
                    f"checkpoint PRNG base {name!r} does not match this "
                    f"simulation's (different seed or network config)")
        mob = state["mobility"]
        for name, cur in (("x0", self.mobility.x0),
                          ("speeds", self.mobility.speeds),
                          ("jitter_phase", self.mobility._jitter_phase)):
            if not np.array_equal(np.asarray(mob[name], np.float64),
                                  np.asarray(cur, np.float64)):
                raise ValueError(
                    f"checkpoint mobility field {name!r} does not match "
                    f"this simulation's configuration")
        conv = np.asarray if self.multihost else jnp.asarray
        self.params = jax.tree.map(conv, state["params"])
        self.participation = part.astype(np.int64)
        self.last_mask = np.asarray(state["last_mask"])
        if faults.active("overflow", "resume"):
            # chaos knob: clamp the windowed election's bucket capacity
            # so every post-resume round overflows and exercises the
            # dense-recovery path (masks stay exact by construction)
            self.stage_cfg = dataclasses.replace(self.stage_cfg,
                                                 elect_capacity=1)

    def _round_row(self, rnd: int, host: Dict, acc_count: jax.Array,
                   n_test: int) -> Dict[str, float]:
        """Resolve the round's metrics row (blocks on the accuracy
        count — the round's second and last device read).

        The async columns (active-fleet size, stale-update fraction,
        effective cohort size, rounds-behind histogram) are emitted for
        every server so the sweep CSV schema is uniform; under the
        synchronous barrier they are the degenerate values (everything
        active and on time) and the event server overrides them from its
        tick counters."""
        n_selected = int(host["n_selected"])
        survivors = np.asarray(host["survivors"])
        n_agg = int(survivors.sum())
        row = {"round": rnd,
               "accuracy": float(acc_count) / float(n_test),
               "n_selected": n_selected,
               "n_aggregated": n_agg,
               "n_straggler": int(host["n_straggler"]),
               "n_active": int(host.get("n_active", self.n)),
               "stale_frac": 0.0,
               "n_effective": float(n_agg),
               "rounds_behind_hist": f"{n_agg}/0/0/0",
               "mean_eval_selected": float(host["mean_eval_selected"])}
        row.update(self._comm_accounting(n_selected))
        return row

    def run(self, n_rounds: Optional[int] = None,
            overlap: Optional[bool] = None, *,
            checkpointer=None,
            resume: Optional[bool] = None) -> List[Dict[str, float]]:
        """Drive ``n`` rounds; ``overlap`` defaults to the run config's
        round-ahead scheduler setting.  ``RunConfig(server="event")``
        (or any async knob) routes through the event-driven server.

        Preemption safety (ISSUE 10): with a ``checkpointer`` (or the
        run config's ``checkpoint_dir``) the complete round state is
        snapshotted every ``checkpoint_every`` rounds; ``resume``
        (default: the run config's) restores the latest good snapshot
        and continues — the finished rows, masks and params are pinned
        bit-identical to an uninterrupted run."""
        n = n_rounds or self.cfg.n_rounds
        if self.run_cfg.server == "event":
            from repro.fl.async_server import EventDrivenServer
            return EventDrivenServer(self).run(n, overlap=overlap,
                                               checkpointer=checkpointer,
                                               resume=resume)
        ckpt = build_round_checkpointer(self.run_cfg, checkpointer)
        resume = self.run_cfg.resume if resume is None else resume
        rows, start = resume_rows(self, ckpt, resume)
        if overlap is None:
            overlap = self.run_cfg.overlap_rounds
        if overlap:
            return self.run_overlapped(n, start=start, rows=rows,
                                       checkpointer=ckpt)
        lead = not self.multihost or jax.process_index() == 0
        for r in range(start, n):
            rows.append(self.run_round(r))
            checkpoint_round(self, ckpt, r, rows, lead=lead)
        return rows

    def run_overlapped(self, n_rounds: int, *, start: int = 0,
                       rows: Optional[List[Dict[str, float]]] = None,
                       checkpointer=None) -> List[Dict[str, float]]:
        """Round-ahead pipelined driver: identical rounds, pipelined
        dispatch.

        The selection prefix is pure in ``(statics, params, rnd, keys)``
        and training/aggregation only *dispatch* asynchronously, so
        round r+1's prefix can be enqueued on the ``params_{r+1}``
        device future as soon as round r's trainers are queued — before
        round r's metrics are read.  The only hard fence per round is
        the ``device_get`` at the cohort gather (survivor indices must
        be concrete to slice the fixed-shape stacks); the accuracy read
        happens after the round-ahead dispatch, so the device never
        idles waiting for host bookkeeping between rounds.  Rounds are
        bit-identical to the serial driver — same ops in the same
        order, only enqueued earlier (pinned in
        tests/test_probe_fuzzy.py).

        Resume slots in transparently: the prefix is pure in
        ``(params, rnd)``, so the round-ahead dispatch a kill threw away
        is re-issued identically from the restored ``params`` — rounds
        ``start..n`` replay the uninterrupted schedule bit-for-bit."""
        rows = [] if rows is None else rows
        if start >= n_rounds:
            return rows
        lead = not self.multihost or jax.process_index() == 0
        state = self.selection_state(start)
        for r in range(start, n_rounds):
            host = jax.device_get(state)     # fence: the cohort gather
            host = self.resolve_elect_overflow(r, host)
            self._dispatch_training(r, host)
            acc, n_test = evaluate_accuracy_async(
                self._eval_params(), self.test_images, self.test_labels,
                batch=256)
            if r + 1 < n_rounds:             # round-ahead: r+1's prefix
                state = self.selection_state(r + 1)
            rows.append(self._round_row(r, host, acc, n_test))
            checkpoint_round(self, checkpointer, r, rows, lead=lead)
        return rows
