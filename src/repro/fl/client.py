"""Participant-side logic: Eq. 7 loss probe + Eq. 1 local SGD training.

The local trainer is one jitted function over fixed-capacity padded
arrays (invalid samples masked out of the loss) — the whole local round
is a single XLA program.

Three call shapes are exposed:

- per-client (``dataset_loss`` / ``local_train``), the reference path the
  loop engine uses;
- batched over a leading client axis (``dataset_loss_batch`` /
  ``local_train_batch``) — one compile and one dispatch for a whole
  cohort instead of ``O(n_clients)``.  The round engine issues one such
  call per capacity group, so every client in a call shares its group's
  ``cap`` and ``steps_per_epoch`` (small Table-3 clients stop paying for
  the 4500-sample group's step count);
- packed (``dataset_loss_packed``): the Eq. 7 probe over a flat
  concatenation of every client's probe samples.  The batched round
  engine precomputes the packing once (client membership is static
  across rounds); since the mesh-sharded client axis, the packing is
  *client-aligned* — each client padded to whole probe batches — which
  spends some forward FLOPs on sentinel rows but makes the per-client
  losses independent of how the sample axis is split across devices
  (see ``FLSimulation._build_packed_probe``).

XLA:CPU notes (measured on the 2-core dev box, jax 0.4.37):

- ``lax.scan``/``while`` loop bodies execute on a slow path (~5-10x:
  conv gradients drop from ~50 to ~5 GFLOPS).  All chunk/step loops here
  fully unroll when the trip count is <= ``_UNROLL_LIMIT`` and fall back
  to ``lax.scan`` for Table-3-scale epoch counts where unrolling would
  blow up compile time.
- the epoch shuffle is a one-hot permutation matmul rather than a row
  gather: a batched gather of image rows hits a scalar gather path; the
  matmul form is a GEMM and bitwise-equal (each output row is 1*x plus
  exact zeros).
- ``local_train_batch`` scans steps OUTSIDE and vmaps clients INSIDE;
  ``vmap(scan(...))`` fuses into one while loop and hits the same slow
  path as above.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import CNNConfig
from repro.fl.aggregation import prox_grad
from repro.models.cnn import cnn_forward, cnn_sample_losses
from repro.train.optim import sgd_update

Params = Any

# the shared XLA:CPU loop slow-path policy (repro/scanopt.py): loops up
# to _UNROLL_LIMIT unroll into straight-line XLA; longer step loops (the
# Table-3 cap-4500 trainer: 225 steps/epoch) chunk-unroll with
# ``lax.scan(..., unroll=_SCAN_UNROLL)``, amortizing the per-iteration
# while-loop overhead over a block of straight-line steps (~1.1x on the
# conv-grad-dominated trainer body, benchmarks/engine_throughput.py
# trainer_unroll).  Math is unchanged: same steps, same order.
from repro.scanopt import SCAN_UNROLL as _SCAN_UNROLL
from repro.scanopt import UNROLL_LIMIT as _UNROLL_LIMIT

# epoch-shuffle form: the one-hot matmul is O(cap^2) — a clear win over
# the scalar gather path at small caps, a memory/FLOP blowup at the
# Table-3 full profile (cap ~4500, where a (C, cap, cap) one-hot is GBs)
_SHUFFLE_MATMUL_CAP = 512


def _shuffle_rows(flat: jax.Array, perm: jax.Array,
                  cap: int) -> jax.Array:
    """flat: (..., cap, D) reordered to flat[..., perm, :] — one-hot
    matmul below _SHUFFLE_MATMUL_CAP (bitwise-equal: each output row is
    1*x plus exact zeros), plain gather above it."""
    if cap <= _SHUFFLE_MATMUL_CAP:
        onehot = (perm[..., :, None] == jnp.arange(cap)).astype(flat.dtype)
        return onehot @ flat
    return jnp.take_along_axis(flat, perm[..., :, None], axis=-2)


def _chunk_reduce(body, init, n: int):
    """acc = body(acc, i) for i in range(n) — unrolled when small,
    chunk-unrolled scan past the limit."""
    if n <= _UNROLL_LIMIT:
        acc = init
        for i in range(n):
            acc = body(acc, jnp.int32(i))
        return acc
    return jax.lax.scan(lambda a, i: (body(a, i), None), init,
                        jnp.arange(n), unroll=_SCAN_UNROLL)[0]


# --------------------------------------------------------------------------
# Eq. 7 probe
# --------------------------------------------------------------------------

def _dataset_loss(params: Params, images: jax.Array, labels: jax.Array,
                  n_valid: jax.Array, batch: int) -> jax.Array:
    """Eq. 7 body: mean per-sample loss of the *global* model over the
    local dataset, no gradient update.  images: (cap, 28,28,1)."""
    cap = images.shape[0]
    pad = (-cap) % batch
    if pad:
        images = jnp.pad(images, ((0, pad), (0, 0), (0, 0), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
    nb = images.shape[0] // batch

    def body(acc, i):
        im = jax.lax.dynamic_slice_in_dim(images, i * batch, batch)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * batch, batch)
        losses = cnn_sample_losses(params, im, lb)
        idx = i * batch + jnp.arange(batch)
        m = (idx < n_valid).astype(jnp.float32)
        return acc + (losses * m).sum()

    tot = _chunk_reduce(body, jnp.float32(0.0), nb)
    return tot / jnp.maximum(n_valid.astype(jnp.float32), 1.0)


@functools.partial(jax.jit, static_argnames=("batch",))
def dataset_loss(params: Params, images: jax.Array, labels: jax.Array,
                 n_valid: jax.Array, batch: int = 512) -> jax.Array:
    """Per-client Eq. 7 probe.  images: (cap, 28,28,1) -> scalar."""
    return _dataset_loss(params, images, labels, n_valid, batch)


@functools.partial(jax.jit, static_argnames=("n_clients", "batch"))
def dataset_loss_packed(params: Params, images: jax.Array, labels: jax.Array,
                        seg: jax.Array, counts: jax.Array, n_clients: int,
                        batch: int = 512) -> jax.Array:
    """Eq. 7 for a whole cohort in one fused forward pass over packed
    samples.

    images: (S, 28,28,1) — every client's valid probe samples
    concatenated; seg: (S,) client id per sample, ``n_clients`` for
    padding rows; counts: (C,) samples per client.  Returns (C,)
    per-client mean losses."""
    pad = (-images.shape[0]) % batch
    if pad:
        images = jnp.pad(images, ((0, pad),) + ((0, 0),) * (
            images.ndim - 1))
        labels = jnp.pad(labels, (0, pad))
        seg = jnp.pad(seg, (0, pad), constant_values=n_clients)
    nb = images.shape[0] // batch

    def body(acc, i):
        im = jax.lax.dynamic_slice_in_dim(images, i * batch, batch)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * batch, batch)
        sg = jax.lax.dynamic_slice_in_dim(seg, i * batch, batch)
        losses = cnn_sample_losses(params, im, lb)
        # per-client reduction as a one-hot matvec — a scatter-based
        # segment_sum here runs on XLA:CPU's scalar path
        onehot = (sg[:, None] == jnp.arange(n_clients + 1)[None, :]
                  ).astype(jnp.float32)
        return acc + losses @ onehot

    tot = _chunk_reduce(body, jnp.zeros(n_clients + 1, jnp.float32), nb)
    return tot[:n_clients] / jnp.maximum(counts.astype(jnp.float32), 1.0)


@functools.partial(jax.jit, static_argnames=("batch",))
def dataset_loss_batch(params: Params, images: jax.Array, labels: jax.Array,
                       n_valid: jax.Array, batch: int = 512) -> jax.Array:
    """Eq. 7 probe over a stacked (C, cap, ...) cohort in one fused pass.

    Flattens the client axis into the sample axis (shared global params,
    so the whole cohort is one big forward batch) and reduces per client.
    Returns (C,) mean losses."""
    c, cap = images.shape[0], images.shape[1]
    flat_im = images.reshape((c * cap,) + images.shape[2:])
    flat_lb = labels.reshape(c * cap)
    seg = jnp.repeat(jnp.arange(c), cap)
    # mask padding rows into the overflow segment
    valid = jnp.arange(c * cap) % cap < n_valid[seg]
    seg = jnp.where(valid, seg, c)
    return dataset_loss_packed(params, flat_im, flat_lb, seg, n_valid,
                               n_clients=c, batch=batch)


# --------------------------------------------------------------------------
# Eq. 1 local SGD
# --------------------------------------------------------------------------

def _sample_nll(logits: jax.Array, labels: jax.Array,
                mask: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _local_train(params: Params, images: jax.Array, labels: jax.Array,
                 n_valid: jax.Array, key: jax.Array, epochs: int,
                 batch_size: int, steps_per_epoch: int, lr: float,
                 prox_mu: float,
                 scan_unroll: int = _SCAN_UNROLL) -> Tuple[Params, jax.Array]:
    """Eq. 1 local update body.  Returns (params, mean last-epoch loss)."""
    cap = images.shape[0]
    # capacity groups smaller than the nominal batch (45-sample Table-3
    # clients under a larger batch_size) clamp to one full-capacity batch
    # per step rather than slicing past the array end
    batch_size = min(batch_size, cap)
    steps_per_epoch = max(1, steps_per_epoch)
    global_params = params
    flat = images.reshape(cap, -1)
    unroll = epochs * steps_per_epoch <= _UNROLL_LIMIT

    def loss_fn(p, im, lb, m):
        return _sample_nll(cnn_forward(p, im), lb, m)

    def epoch(carry, ekey):
        p, _ = carry
        perm = jax.random.permutation(ekey, cap)
        ep_images = _shuffle_rows(flat, perm, cap).reshape(images.shape)
        ep_labels = labels[perm]
        ep_mask = (perm < n_valid).astype(jnp.float32)

        def bstep(p, i):
            im = jax.lax.dynamic_slice_in_dim(ep_images, i * batch_size,
                                              batch_size)
            lb = jax.lax.dynamic_slice_in_dim(ep_labels, i * batch_size,
                                              batch_size)
            m = jax.lax.dynamic_slice_in_dim(ep_mask, i * batch_size,
                                             batch_size)
            loss, grads = jax.value_and_grad(loss_fn)(p, im, lb, m)
            if prox_mu > 0.0:
                pg = prox_grad(p, global_params, prox_mu)
                grads = jax.tree.map(lambda a, b: a + b, grads, pg)
            return sgd_update(p, grads, lr), loss

        if unroll:
            losses: List[jax.Array] = []
            for i in range(steps_per_epoch):
                p, loss = bstep(p, jnp.int32(i))
                losses.append(loss)
            return (p, jnp.stack(losses).mean()), None
        p, losses = jax.lax.scan(bstep, p, jnp.arange(steps_per_epoch),
                                 unroll=scan_unroll)
        return (p, losses.mean()), None

    keys = jax.random.split(key, epochs)
    carry = (params, jnp.float32(0.0))
    if unroll:
        for e in range(epochs):
            carry, _ = epoch(carry, keys[e])
    else:
        carry, _ = jax.lax.scan(epoch, carry, keys)
    return carry


@functools.partial(jax.jit, static_argnames=("epochs", "batch_size",
                                             "steps_per_epoch", "lr",
                                             "prox_mu", "scan_unroll"))
def local_train(params: Params, images: jax.Array, labels: jax.Array,
                n_valid: jax.Array, key: jax.Array, *, epochs: int,
                batch_size: int, steps_per_epoch: int, lr: float = 0.05,
                prox_mu: float = 0.0,
                scan_unroll: int = _SCAN_UNROLL) -> Tuple[Params, jax.Array]:
    """Per-client Eq. 1 local update loop."""
    return _local_train(params, images, labels, n_valid, key, epochs,
                        batch_size, steps_per_epoch, lr, prox_mu,
                        scan_unroll)


def _local_train_batch(params: Params, images: jax.Array, labels: jax.Array,
                       n_valid: jax.Array, keys: jax.Array, *, epochs: int,
                       batch_size: int, steps_per_epoch: int,
                       lr: float = 0.05, prox_mu: float = 0.0,
                       scan_unroll: int = _SCAN_UNROLL
                       ) -> Tuple[Params, jax.Array]:
    """Eq. 1 local SGD for a whole cohort in one fused call.

    images: (C, cap, 28,28,1), labels: (C, cap), n_valid: (C,), keys:
    (C,)-leading PRNG keys.  Returns (stacked params with a leading client
    axis, (C,) mean last-epoch losses).  Every client starts from the same
    broadcast global ``params``; which rows enter the aggregate is the
    caller's concern (masked FedAvg weights).

    Per-client math is identical to ``local_train`` (same key schedule,
    same permutations, same batches), but the step loop is OUTER and the
    client axis is vmapped INSIDE each step (see module docstring).

    The round engine calls this once per capacity group — every client in
    a call shares one ``cap``/``steps_per_epoch``, and small groups pay
    for their own few steps instead of the largest group's."""
    c, cap = images.shape[0], images.shape[1]
    batch_size = min(batch_size, cap)          # see _local_train
    steps_per_epoch = max(1, steps_per_epoch)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (c,) + x.shape), params)
    global_stacked = stacked
    flat = images.reshape(c, cap, -1)
    unroll = epochs * steps_per_epoch <= _UNROLL_LIMIT

    def loss_fn(p, im, lb, m):
        return _sample_nll(cnn_forward(p, im), lb, m)

    vgrad = jax.vmap(jax.value_and_grad(loss_fn))

    def epoch(carry, ekeys):
        p, _ = carry
        perms = jax.vmap(lambda k: jax.random.permutation(k, cap))(ekeys)
        ep_images = _shuffle_rows(flat, perms, cap).reshape(images.shape)
        ep_labels = jnp.take_along_axis(labels, perms, axis=1)
        ep_mask = (perms < n_valid[:, None]).astype(jnp.float32)

        def bstep(p, i):
            im = jax.lax.dynamic_slice_in_dim(ep_images, i * batch_size,
                                              batch_size, axis=1)
            lb = jax.lax.dynamic_slice_in_dim(ep_labels, i * batch_size,
                                              batch_size, axis=1)
            m = jax.lax.dynamic_slice_in_dim(ep_mask, i * batch_size,
                                             batch_size, axis=1)
            loss, grads = vgrad(p, im, lb, m)
            if prox_mu > 0.0:
                pg = prox_grad(p, global_stacked, prox_mu)  # leafwise, so
                grads = jax.tree.map(lambda a, b: a + b,    # stacked trees
                                     grads, pg)             # work unchanged
            return sgd_update(p, grads, lr), loss

        if unroll:
            losses: List[jax.Array] = []
            for i in range(steps_per_epoch):
                p, loss = bstep(p, jnp.int32(i))
                losses.append(loss)
            return (p, jnp.stack(losses).mean(axis=0)), None
        p, losses = jax.lax.scan(bstep, p, jnp.arange(steps_per_epoch),
                                 unroll=scan_unroll)
        return (p, losses.mean(axis=0)), None

    # per-client epoch keys, split exactly as local_train splits them
    ekeys = jnp.swapaxes(
        jax.vmap(lambda k: jax.random.split(k, epochs))(keys), 0, 1)
    carry = (stacked, jnp.zeros((c,), jnp.float32))
    if unroll:
        for e in range(epochs):
            carry, _ = epoch(carry, ekeys[e])
    else:
        carry, _ = jax.lax.scan(epoch, carry, ekeys)
    return carry


_TRAIN_BATCH_STATICS = ("epochs", "batch_size", "steps_per_epoch", "lr",
                        "prox_mu", "scan_unroll")

local_train_batch = functools.partial(
    jax.jit, static_argnames=_TRAIN_BATCH_STATICS)(_local_train_batch)

# Donating twin for callers whose cohort tensors are single-use — the
# round engine's ``train_groups`` gathers a fresh (bucket, cap, ...)
# stack every round, and donation lets XLA reuse those buffers for the
# trained-model outputs instead of round-tripping through fresh
# allocations.  NEVER use this with arrays that outlive the call (the
# loop engine's persistent per-group device stacks, benchmark re-calls).
local_train_batch_donated = functools.partial(
    jax.jit, static_argnames=_TRAIN_BATCH_STATICS,
    donate_argnums=(1, 2, 3, 4))(_local_train_batch)


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("batch",))
def _count_correct(params: Params, images: jax.Array, labels: jax.Array,
                   batch: int) -> jax.Array:
    nb = images.shape[0] // batch

    def body(acc, i):
        im = jax.lax.dynamic_slice_in_dim(images, i * batch, batch)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * batch, batch)
        pred = jnp.argmax(cnn_forward(params, im), -1)
        return acc + ((pred == lb) & (lb >= 0)).sum()

    return _chunk_reduce(body, jnp.int32(0), nb)


def evaluate_accuracy_async(params: Params, images: jax.Array,
                            labels: jax.Array, batch: int = 1024
                            ) -> Tuple[jax.Array, int]:
    """Dispatch the test-set accuracy count WITHOUT blocking: returns
    ``(correct-count device future, n_samples)``.  The round-ahead
    scheduler resolves the future only after dispatching the next
    round's selection prefix, so the metric read never serializes the
    pipeline."""
    cap = images.shape[0]
    pad = (-cap) % batch
    if pad:
        images = jnp.pad(images, ((0, pad), (0, 0), (0, 0), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    return _count_correct(params, images, labels, batch), cap


def evaluate_accuracy(params: Params, images: jax.Array,
                      labels: jax.Array, batch: int = 1024) -> float:
    correct, cap = evaluate_accuracy_async(params, images, labels, batch)
    return float(correct) / float(cap)
