"""Participant-side logic: Eq. 7 loss probe + Eq. 1 local SGD training.

The local trainer is one jitted function over fixed-capacity padded
arrays (invalid samples masked out of the loss), scanning
epochs x batches — the whole local round is a single XLA program.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_cnn import CNNConfig
from repro.fl.aggregation import prox_grad
from repro.models.cnn import cnn_forward, cnn_sample_losses
from repro.train.optim import sgd_update

Params = Any


@functools.partial(jax.jit, static_argnames=("batch",))
def dataset_loss(params: Params, images: jax.Array, labels: jax.Array,
                 n_valid: jax.Array, batch: int = 512) -> jax.Array:
    """Eq. 7: mean per-sample loss of the *global* model over the local
    dataset, no gradient update.  images: (cap, 28,28,1)."""
    cap = images.shape[0]
    pad = (-cap) % batch
    if pad:
        images = jnp.pad(images, ((0, pad), (0, 0), (0, 0), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
    nb = images.shape[0] // batch

    def body(acc, i):
        im = jax.lax.dynamic_slice_in_dim(images, i * batch, batch)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * batch, batch)
        losses = cnn_sample_losses(params, im, lb)
        idx = i * batch + jnp.arange(batch)
        m = (idx < n_valid).astype(jnp.float32)
        return acc + (losses * m).sum(), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(nb))
    return tot / jnp.maximum(n_valid.astype(jnp.float32), 1.0)


@functools.partial(jax.jit, static_argnames=("epochs", "batch_size",
                                             "steps_per_epoch", "lr",
                                             "prox_mu"))
def local_train(params: Params, images: jax.Array, labels: jax.Array,
                n_valid: jax.Array, key: jax.Array, *, epochs: int,
                batch_size: int, steps_per_epoch: int, lr: float = 0.05,
                prox_mu: float = 0.0) -> Tuple[Params, jax.Array]:
    """Eq. 1 local update loop.  Returns (params, mean last-epoch loss)."""
    cap = images.shape[0]
    global_params = params

    def loss_fn(p, im, lb, m):
        logits = cnn_forward(p, im)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[:, None], axis=-1)[:, 0]
        nll = (logz - gold) * m
        return nll.sum() / jnp.maximum(m.sum(), 1.0)

    def epoch(carry, ekey):
        p, _ = carry
        perm = jax.random.permutation(ekey, cap)

        def bstep(p, i):
            idx = jax.lax.dynamic_slice_in_dim(perm, i * batch_size,
                                               batch_size)
            im = images[idx]
            lb = labels[idx]
            m = (idx < n_valid).astype(jnp.float32)
            loss, grads = jax.value_and_grad(loss_fn)(p, im, lb, m)
            if prox_mu > 0.0:
                pg = prox_grad(p, global_params, prox_mu)
                grads = jax.tree.map(lambda a, b: a + b, grads, pg)
            return sgd_update(p, grads, lr), loss

        p, losses = jax.lax.scan(bstep, p, jnp.arange(steps_per_epoch))
        return (p, losses.mean()), None

    keys = jax.random.split(key, epochs)
    (params, last_loss), _ = jax.lax.scan(epoch, (params, jnp.float32(0.0)),
                                          keys)
    return params, last_loss


@functools.partial(jax.jit, static_argnames=("batch",))
def _count_correct(params: Params, images: jax.Array, labels: jax.Array,
                   batch: int) -> jax.Array:
    nb = images.shape[0] // batch

    def body(acc, i):
        im = jax.lax.dynamic_slice_in_dim(images, i * batch, batch)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * batch, batch)
        pred = jnp.argmax(cnn_forward(params, im), -1)
        ok = ((pred == lb) & (lb >= 0)).sum()
        return acc + ok, None

    tot, _ = jax.lax.scan(body, jnp.int32(0), jnp.arange(nb))
    return tot


def evaluate_accuracy(params: Params, images: jax.Array,
                      labels: jax.Array, batch: int = 1024) -> float:
    cap = images.shape[0]
    pad = (-cap) % batch
    if pad:
        images = jnp.pad(images, ((0, pad), (0, 0), (0, 0), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    return float(_count_correct(params, images, labels, batch)) / float(cap)
