"""Non-i.i.d. dataset partitioner (paper §6.1).

Rules reproduced from the paper:
- each vehicle draws from ``classes_per_client`` classes (9 / 6 / 2 in the
  three Fig. 8 experiments), each class contributing an identical quantity;
- quantity is unbalanced: vehicles 0-11 get ~4500 samples, vehicles 12-29
  get ~45 (Table 3);
- no sample is duplicated across vehicles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class PartitionConfig:
    n_clients: int = 30
    classes_per_client: int = 9
    big_clients: int = 12           # vehicles 0..11
    big_quantity: int = 4500
    small_quantity: int = 45
    num_classes: int = 10
    seed: int = 0


def client_quantities(cfg: PartitionConfig) -> np.ndarray:
    q = np.full(cfg.n_clients, cfg.small_quantity, np.int64)
    q[: cfg.big_clients] = cfg.big_quantity
    return q


def partition(images: np.ndarray, labels: np.ndarray,
              cfg: PartitionConfig) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Split (images, labels) across clients.  Returns a list of per-client
    (images, labels).  Raises if the source dataset is too small to honor
    the no-duplication rule."""
    rng = np.random.default_rng(cfg.seed + 17)
    pools = {c: list(rng.permutation(np.where(labels == c)[0]))
             for c in range(cfg.num_classes)}
    quantities = client_quantities(cfg)

    out = []
    for i in range(cfg.n_clients):
        # class subset: rotate so coverage is even across clients
        classes = [(i + j) % cfg.num_classes
                   for j in range(cfg.classes_per_client)]
        per_class = int(quantities[i]) // cfg.classes_per_client
        idx: List[int] = []
        for c in classes:
            if len(pools[c]) < per_class:
                raise ValueError(
                    f"class {c} exhausted for client {i}: "
                    f"need {per_class}, have {len(pools[c])}")
            take, pools[c] = pools[c][:per_class], pools[c][per_class:]
            idx.extend(take)
        idx = np.asarray(idx)
        out.append((images[idx], labels[idx]))
    return out


def stack_clients(parts: List[Tuple[np.ndarray, np.ndarray]],
                  batch_size: int = 1,
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad every client to one uniform capacity and stack.

    The capacity is the largest client's quantity rounded up to a multiple
    of ``batch_size``, so the batched round engine can vmap one fixed-shape
    local trainer over the client axis.  Trade-off: with extreme quantity
    skew (Table 3 full profile: 4500 vs 45) small clients spend most local
    steps on masked padding slots — the per-capacity-group trainer that
    would fix this is an open ROADMAP item.  Returns
    (images (C, cap, 28, 28, 1), labels (C, cap), n_valid (C,))."""
    cap = max(max(len(p[1]) for p in parts), batch_size)
    cap = int(np.ceil(cap / batch_size) * batch_size)
    return pad_clients(parts, cap)


def pad_clients(parts: List[Tuple[np.ndarray, np.ndarray]],
                cap: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-client datasets into fixed-capacity arrays.

    Returns (images (C, cap, 28, 28, 1), labels (C, cap), n_valid (C,)).
    Valid samples occupy the leading positions."""
    c = len(parts)
    img_shape = parts[0][0].shape[1:]
    images = np.zeros((c, cap) + img_shape, np.float32)
    labels = np.zeros((c, cap), np.int32)
    n_valid = np.zeros((c,), np.int32)
    for i, (im, lb) in enumerate(parts):
        n = min(len(lb), cap)
        images[i, :n] = im[:n]
        labels[i, :n] = lb[:n]
        n_valid[i] = n
    return images, labels, n_valid
