"""Non-i.i.d. dataset partitioner (paper §6.1) + capacity-grouped storage.

Rules reproduced from the paper:
- each vehicle draws from ``classes_per_client`` classes (9 / 6 / 2 in the
  three Fig. 8 experiments), each class contributing an identical quantity;
- quantity is unbalanced: vehicles 0-11 get ~4500 samples, vehicles 12-29
  get ~45 (Table 3);
- no sample is duplicated across vehicles.

Storage layout: the Table-3 profile is radically quantity-skewed, so
padding every client to the single largest quantity makes small clients
spend ~99% of their local-SGD steps on masked padding rows.
``stack_clients`` therefore buckets clients by capacity (quantity rounded
up to a whole number of batches) and returns one fixed-shape
``ClientGroup`` per distinct capacity — the round engine vmaps one local
trainer per group instead of one trainer over a uniform max-cap stack.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import numpy as np


@dataclass(frozen=True)
class PartitionConfig:
    n_clients: int = 30
    classes_per_client: int = 9
    big_clients: int = 12           # vehicles 0..11
    big_quantity: int = 4500
    small_quantity: int = 45
    num_classes: int = 10
    seed: int = 0


@dataclass(frozen=True)
class ClientGroup:
    """One capacity bucket of the stacked client datasets.

    ``client_ids`` maps the group-local leading axis back to global client
    indices; ``images``/``labels`` are fixed-shape ``(G, cap, ...)`` stacks
    (host ``np.ndarray`` or device ``jax.Array`` depending on the engine);
    valid samples occupy the leading ``n_valid[i]`` rows of each client."""
    client_ids: np.ndarray          # (G,) int64, global client indices
    images: Any                     # (G, cap, 28, 28, 1)
    labels: Any                     # (G, cap)
    n_valid: np.ndarray             # (G,) int32
    cap: int

    @property
    def size(self) -> int:
        return len(self.client_ids)


def client_quantities(cfg: PartitionConfig) -> np.ndarray:
    q = np.full(cfg.n_clients, cfg.small_quantity, np.int64)
    q[: cfg.big_clients] = cfg.big_quantity
    return q


def shard_client_range(n_clients: int, n_shards: int, shard: int) -> range:
    """The global client indices owned by mesh shard ``shard`` under the
    sharded round pipeline's contiguous equal-width layout: clients are
    padded to a mesh multiple and split into ``n_shards`` runs of
    ``ceil(n / K)``, so shard ``d`` owns ``[d*w, min((d+1)*w, n))``.

    Single source of truth for per-shard data loading — the packed-probe
    regioning in ``fl/rounds.py`` and a ``--multihost`` process deciding
    which clients' samples to materialize both derive from it.  The last
    shards of an ``n % K != 0`` fleet own fewer (possibly zero) real
    clients; the pipeline pads them with invalid slots."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1: {n_shards}")
    if not 0 <= shard < n_shards:
        raise ValueError(f"shard {shard} out of range [0, {n_shards})")
    width = -(-n_clients // n_shards)        # ceil(n / K)
    return range(shard * width, min((shard + 1) * width, n_clients))


def group_capacity(quantity: int, batch_size: int) -> int:
    """Smallest whole number of batches covering ``quantity`` samples —
    always >= ``batch_size``, so every capacity group takes at least one
    local step per epoch (45-sample Table-3 clients included)."""
    q = max(int(quantity), 1)
    return int(np.ceil(q / batch_size) * batch_size)


def steps_per_epoch(cap: int, batch_size: int) -> int:
    """Local SGD steps per epoch at capacity ``cap`` — guarded against 0
    so groups smaller than the batch size still train."""
    return max(1, cap // batch_size)


def partition(images: np.ndarray, labels: np.ndarray,
              cfg: PartitionConfig) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Split (images, labels) across clients.  Returns a list of per-client
    (images, labels).  Raises if the source dataset is too small to honor
    the no-duplication rule."""
    rng = np.random.default_rng(cfg.seed + 17)
    pools = {c: list(rng.permutation(np.where(labels == c)[0]))
             for c in range(cfg.num_classes)}
    quantities = client_quantities(cfg)

    out = []
    for i in range(cfg.n_clients):
        # class subset: rotate so coverage is even across clients
        classes = [(i + j) % cfg.num_classes
                   for j in range(cfg.classes_per_client)]
        per_class = int(quantities[i]) // cfg.classes_per_client
        idx: List[int] = []
        for c in classes:
            if len(pools[c]) < per_class:
                raise ValueError(
                    f"class {c} exhausted for client {i}: "
                    f"need {per_class}, have {len(pools[c])}")
            take, pools[c] = pools[c][:per_class], pools[c][per_class:]
            idx.extend(take)
        idx = np.asarray(idx)
        out.append((images[idx], labels[idx]))
    return out


def stack_clients(parts: List[Tuple[np.ndarray, np.ndarray]],
                  batch_size: int = 1,
                  uniform: bool = False) -> List[ClientGroup]:
    """Stack per-client datasets into capacity-grouped fixed-shape tensors.

    Each client's capacity is its quantity rounded up to a whole number of
    batches (``group_capacity``); clients sharing a capacity are stacked
    into one ``ClientGroup``, largest capacity first.  The Table-3 full
    profile (4500 vs 45 samples, batch 20) yields exactly two groups —
    a 4500-cap and a 60-cap one — so small clients train 3 steps/epoch
    instead of 225 steps of mostly masked padding.

    ``uniform=True`` reproduces the single max-capacity stack (every
    client padded to the largest group's cap, one group) — kept as the
    comparison baseline for ``benchmarks/engine_throughput.py``."""
    caps = [group_capacity(len(p[1]), batch_size) for p in parts]
    if uniform:
        caps = [max(caps)] * len(parts)
    groups = []
    for cap in sorted(set(caps), reverse=True):
        ids = np.asarray([i for i, c in enumerate(caps) if c == cap],
                         np.int64)
        im, lb, nv = pad_clients([parts[i] for i in ids], cap)
        groups.append(ClientGroup(client_ids=ids, images=im, labels=lb,
                                  n_valid=nv, cap=cap))
    return groups


def pad_clients(parts: List[Tuple[np.ndarray, np.ndarray]],
                cap: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-client datasets into fixed-capacity arrays.

    Returns (images (C, cap, 28, 28, 1), labels (C, cap), n_valid (C,)).
    Valid samples occupy the leading positions."""
    c = len(parts)
    img_shape = parts[0][0].shape[1:]
    images = np.zeros((c, cap) + img_shape, np.float32)
    labels = np.zeros((c, cap), np.int32)
    n_valid = np.zeros((c,), np.int32)
    for i, (im, lb) in enumerate(parts):
        n = min(len(lb), cap)
        images[i, :n] = im[:n]
        labels[i, :n] = lb[:n]
        n_valid[i] = n
    return images, labels, n_valid
