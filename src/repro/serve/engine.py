"""Serving engine: batched prefill + greedy/temperature decode loop.

``serve_step`` (one token for the whole batch against the cache) is the
function the decode-shape dry-runs lower; ``generate`` drives it with
``lax.scan`` for end-to-end examples.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models import registry as R


def make_serve_step(cfg: ArchConfig, context: int) -> Callable:
    """serve_step(params, cache, tokens (B,1)) -> (logits, cache)."""
    window = 0
    if cfg.sliding_window and context > cfg.sliding_window:
        window = cfg.sliding_window

    def serve_step(params, cache, tokens):
        return tfm.decode_step(cfg, params, cache, tokens, window=window)

    return serve_step


def generate(cfg: ArchConfig, params, batch: Dict[str, jax.Array],
             max_new_tokens: int, *, temperature: float = 0.0,
             key: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prefill the prompt then decode ``max_new_tokens`` greedily (or with
    temperature sampling).  Returns (tokens (B, max_new_tokens), info)."""
    prompt_len = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        prompt_len += cfg.num_prefix_tokens
    context = prompt_len + max_new_tokens
    logits, cache = tfm.prefill(cfg, params, batch, context=context)
    window = 0
    if cfg.sliding_window and context > cfg.sliding_window:
        window = cfg.sliding_window

    def sample(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg[:, -1] / temperature).astype(
            jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok0 = sample(logits, key)

    def body(carry, k):
        tok, cache = carry
        lg, cache = tfm.decode_step(cfg, params, cache, tok[:, None],
                                    window=window)
        nxt = sample(lg, k)
        return (nxt, cache), nxt

    keys = jax.random.split(key, max_new_tokens)
    (last, cache), toks = jax.lax.scan(body, (tok0, cache), keys)
    out = jnp.concatenate([tok0[:, None], toks.T], axis=1)[:, :max_new_tokens]
    return out, {"cache": cache, "prompt_len": prompt_len}
