from repro.serve.engine import generate, make_serve_step

__all__ = ["generate", "make_serve_step"]
