"""XLA:CPU loop slow-path mitigation — the shared unroll policy.

PR 1 measured ``lax.scan``/``lax.while`` loop bodies executing ~5-10x
slower than the same ops as straight-line code on XLA:CPU (conv
gradients drop from ~50 to ~5 GFLOPS inside a loop body).  The fix has
two regimes, first applied in ``fl/client.py`` and now shared by every
scan/fori hot loop in the repo (kernels/selective_scan.py,
kernels/wkv6.py, train/step.py):

- trip counts <= ``UNROLL_LIMIT`` unroll fully into straight-line XLA
  (compile time stays bounded, runtime leaves the slow path entirely);
- longer loops chunk-unroll with ``unroll=SCAN_UNROLL``, amortizing the
  per-iteration loop overhead over a block of straight-line steps while
  keeping compile time linear in the (small) unroll factor.

Neither regime changes the math: the same iterations run in the same
order, only the loop-carrier structure differs.
"""
from __future__ import annotations

# loops up to this many iterations are unrolled into straight-line XLA
# (past it, compile time beats the while-loop slow path)
UNROLL_LIMIT = 64

# chunk-unroll factor for loops too long to unroll fully (the win is
# bounded by how much of the body is loop overhead — ~1.1x on conv-grad
# bodies, larger on element-wise recurrences; free at runtime either way)
SCAN_UNROLL = 8


def scan_unroll(n: int, limit: int = UNROLL_LIMIT,
                chunk: int = SCAN_UNROLL) -> int:
    """The ``unroll=`` argument for a scan/fori of ``n`` iterations under
    the shared policy: full unroll under ``limit``, chunk past it."""
    if n <= 0:
        return 1
    return n if n <= limit else min(n, chunk)
