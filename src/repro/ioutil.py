"""Atomic file writes (ISSUE 10): tmp file + ``os.replace``.

Every durable artifact this repo emits — the sweep CSV, the cumulative
``BENCH_*.json`` bench artifacts, ``fl_sim``'s results JSON and the
round checkpoints — goes through ``write_atomic``: the payload lands in
a same-directory temporary file, is fsync'd, and is renamed over the
target in one ``os.replace``.  POSIX rename atomicity means a reader
(or a resumed run) sees either the complete old file or the complete
new file; a SIGKILL mid-write can never leave a torn artifact, only a
stray ``*.tmp-*`` file that the next successful write ignores.

``sha256_file`` backs the checkpoint manifest checksums
(``repro.train.checkpoint``): corruption *between* runs (partial disk
flush on power loss, bit rot, deliberate fault injection) is detected
at read time instead of being silently loaded.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Union


def write_atomic(path: Union[str, os.PathLike], data: Union[str, bytes],
                 *, sync: bool = True) -> None:
    """Write ``data`` to ``path`` atomically (tmp file + ``os.replace``).

    The temporary file lives in the target's directory so the final
    rename never crosses a filesystem boundary.  On any failure the
    temporary file is removed and the previous ``path`` contents (if
    any) are left untouched."""
    path = os.fspath(path)
    directory = os.path.dirname(os.path.abspath(path))
    os.makedirs(directory, exist_ok=True)
    payload = data.encode("utf-8") if isinstance(data, str) else data
    fd, tmp = tempfile.mkstemp(
        prefix=os.path.basename(path) + ".tmp-", dir=directory)
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
            f.flush()
            if sync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_atomic_json(path: Union[str, os.PathLike], obj: Any,
                      **json_kwargs: Any) -> None:
    """``json.dump`` through ``write_atomic`` (one serialized payload,
    one rename)."""
    write_atomic(path, json.dumps(obj, **json_kwargs))


def sha256_file(path: Union[str, os.PathLike],
                chunk_bytes: int = 1 << 20) -> str:
    """Hex sha256 of a file's contents (streamed)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            chunk = f.read(chunk_bytes)
            if not chunk:
                break
            h.update(chunk)
    return h.hexdigest()
