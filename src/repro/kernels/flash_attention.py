"""Pallas TPU kernel: flash attention (online-softmax, GQA, causal /
sliding-window / prefix-LM masking).

This is the EXPERIMENTS §Perf "next lever" for the dense architectures:
the jnp flash path (models/attention.py) materialises every
(q_chunk, kv_chunk) score tile to HBM at XLA:CPU fusion granularity,
which is what dominates the train/prefill memory terms.  Here the tiles
live in VMEM: grid (B*Hq, Sq/BQ, Skv/BK) with the kv axis innermost
(sequential), running max/sum/accumulator in VMEM scratch, one HBM write
of the normalized output per q block.

GQA is handled in the index map: q head h reads kv head h // group.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128
BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, window: int, prefix_len: int,
            sq: int, skv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                  # (BQ, Dh)
    k = k_ref[0].astype(jnp.float32)                  # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    bq, bk = s.shape
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = (q_pos < sq) & (kv_pos < skv)
    if causal:
        ca = kv_pos <= q_pos
        if window:
            ca &= (q_pos - kv_pos) < window
        if prefix_len:
            ca |= kv_pos < prefix_len
        ok &= ca
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc_sc[...]
                    / jnp.maximum(l_sc[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           prefix_len: int = 0,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh) -> (B, Sq, Hq, Dh).

    Positions are the natural 0..S-1 ranges (self-attention layout;
    ``causal=False`` gives full bidirectional attention).
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    bq = min(BQ, sq)
    bk = min(BK, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qq = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kk = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vv = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    sqp, skp = sq + pad_q, skv + pad_k

    # (B*H, S, Dh) layouts
    qr = qq.transpose(0, 2, 1, 3).reshape(b * hq, sqp, dh)
    kr = kk.transpose(0, 2, 1, 3).reshape(b * hkv, skp, dh)
    vr = vv.transpose(0, 2, 1, 3).reshape(b * hkv, skp, dh)

    grid = (b * hq, sqp // bq, skp // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, prefix_len=prefix_len,
                          sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda i, qj, kj: (i, qj, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda i, qj, kj, g=g: (i // g, kj, 0)),
            pl.BlockSpec((1, bk, dh),
                         lambda i, qj, kj, g=g: (i // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda i, qj, kj: (i, qj, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sqp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, hq, sqp, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]
