"""Pallas TPU kernel: batched Mamdani fuzzy evaluation (paper §5.3).

At IoV scale the evaluator runs for *every participant every round*
(3.09 M vehicles in the paper's Tokyo example), which makes it a bulk
VPU workload: per participant, 4 Gaussian membership lookups x 3
linguistic levels, 81 min-conjunction rules, max-aggregation into 9
output levels and a COG division.

TPU layout: participants live on the lane axis.  Inputs are transposed
to (V=4, P) so a block is (4, BLOCK_P) — 4 sublanes x 128*k lanes.  The
81-rule table is a *static* Python constant, so the rule loop fully
unrolls into vectorised min/max ops; there is no gather in the kernel.

The block size adapts to the fleet: ``BLOCK_P`` is a *cap*, and a
P-lane batch runs at ``min(BLOCK_P, P rounded up to a lane multiple)``
— a 96-client fleet evaluates in one (4, 128) block instead of padding
10.7x to 1024 dead lanes (the fixed-block regression this replaces).

``mamdani_lanes`` is the kernel body's inference core (memberships ->
81 static rules -> COG) over a ``(V, P)`` lane-axis block; the fused
probe->evaluate kernel (``kernels/probe_fuzzy.py``) reuses it verbatim
so the two kernels cannot drift apart.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_P = 1024       # cap; see block_p()
LANE = 128           # TPU lane width — the minimum/alignment block unit
NUM_VARS = 4
NUM_LEVELS = 3       # per-variable linguistic levels (low / mid / high)
NUM_OUT = 9          # L0..L8


def block_p(p: int) -> int:
    """Participant block size for a P-lane batch: the next lane multiple
    of P, capped at ``BLOCK_P`` — small fleets stop paying for dead
    lanes (96 clients: 128-lane block, not 1024)."""
    return min(BLOCK_P, -(-p // LANE) * LANE)


def mamdani_lanes(x: jax.Array, means: jax.Array, sigmas: jax.Array,
                  centers: jax.Array, rule_table: tuple,
                  rule_levels: tuple) -> jax.Array:
    """Mamdani inference over a lane-axis block: x (V, P) in [0, 1] ->
    evaluations (P,).  The static rule tuples unroll into vectorised
    min/max chains — shared by the standalone and fused kernels."""
    # memberships mu[v][l]: (P,)
    mu = []
    for v in range(NUM_VARS):
        row = []
        for l in range(NUM_LEVELS):
            d = (x[v, :] - means[v, l]) / sigmas[v, l]
            row.append(jnp.exp(-0.5 * d * d))
        mu.append(row)

    # 81 static rules: firing = min over the 4 antecedents
    beta = [None] * NUM_OUT                          # max-aggregated per level
    for r in range(len(rule_table)):
        idx = rule_table[r]
        f = mu[0][idx[0]]
        for v in range(1, NUM_VARS):
            f = jnp.minimum(f, mu[v][idx[v]])
        lv = rule_levels[r]
        beta[lv] = f if beta[lv] is None else jnp.maximum(beta[lv], f)

    num = jnp.zeros_like(x[0, :])
    den = jnp.zeros_like(x[0, :])
    for j in range(NUM_OUT):
        if beta[j] is None:
            continue
        num = num + centers[0, j] * beta[j]
        den = den + beta[j]
    return num / jnp.maximum(den, 1e-9)


def _kernel(x_ref, inv_max_ref, means_ref, sigmas_ref, centers_ref, o_ref, *,
            rule_table: tuple, rule_levels: tuple, normalize: bool):
    x = x_ref[...]                                   # (V, P)
    if normalize:                                    # Eq. 8 in-kernel
        x = jnp.clip(x * inv_max_ref[...], 0.0, 1.0)
    o_ref[...] = mamdani_lanes(x, means_ref[...], sigmas_ref[...],
                               centers_ref[...], rule_table,
                               rule_levels)[None, :]


def static_rules(rule_table: np.ndarray,
                 rule_levels: np.ndarray) -> Tuple[tuple, tuple]:
    """Host constants -> hashable static tuples the kernels unroll over."""
    table = tuple(tuple(int(i) for i in row) for row in np.asarray(rule_table))
    levels = tuple(int(l) for l in np.asarray(rule_levels))
    return table, levels


def fuzzy_eval_pallas(x: jax.Array, means: jax.Array, sigmas: jax.Array,
                      rule_table: np.ndarray, rule_levels: np.ndarray,
                      level_centers: jax.Array, interpret: bool = True,
                      normalize: bool = False) -> jax.Array:
    """x: (P, V) in [0,1] -> evaluations (P,).

    ``normalize=True`` accepts *raw* feature columns and applies Eq. 8
    per-column max-scaling inside the kernel (the global column maxima
    are a cheap jnp prepass over the un-padded input; the padded rows
    are zeros, so they cannot raise a maximum).

    rule_table (R,V) / rule_levels (R,) are host-side numpy constants —
    they are baked into the kernel as static unrolled rules.
    """
    p, v = x.shape
    assert v == NUM_VARS
    bp = block_p(p)
    pad = (-p) % bp
    xp = jnp.pad(x, ((0, pad), (0, 0))).T.astype(jnp.float32)   # (V, P')
    pp = p + pad
    inv_max = (1.0 / jnp.maximum(x.max(axis=0), 1e-9) if normalize
               else jnp.ones((v,))).astype(jnp.float32)[:, None]
    table, levels = static_rules(rule_table, rule_levels)

    out = pl.pallas_call(
        functools.partial(_kernel, rule_table=table, rule_levels=levels,
                          normalize=normalize),
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((NUM_VARS, bp), lambda i: (0, i)),
            pl.BlockSpec((NUM_VARS, 1), lambda i: (0, 0)),
            pl.BlockSpec((NUM_VARS, NUM_LEVELS), lambda i: (0, 0)),
            pl.BlockSpec((NUM_VARS, NUM_LEVELS), lambda i: (0, 0)),
            pl.BlockSpec((1, NUM_OUT), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), jnp.float32),
        interpret=interpret,
    )(xp, inv_max, means.astype(jnp.float32), sigmas.astype(jnp.float32),
      level_centers.astype(jnp.float32)[None, :])
    return out[0, :p]
