"""Pallas TPU kernel: fused Eq. 7 probe -> Eq. 8 -> Mamdani evaluation.

The per-round selection hot path runs the probe CNN forward over every
participant's probe samples, normalizes the four objective columns and
evaluates the 81-rule Mamdani base — previously three dispatches
(``dataset_loss_packed`` -> transpose/stack -> ``fuzzy_eval_pallas``)
with the packed activations round-tripping through HBM between them.
This kernel fuses the chain into ONE launch:

- grid over blocks of ``block_s`` packed probe samples (TPU grid order
  is sequential, so the per-client loss accumulator lives in VMEM
  scratch and carries across blocks);
- per block: conv1 -> pool -> conv2 -> pool -> fc1 -> fc2 staged in
  VMEM, the convolutions expressed as im2col GEMMs (25 static shifted
  slices concatenated on the channel axis, then one MXU matmul — no
  conv primitive exists in Mosaic);
- the per-sample NLL reduces into per-client lanes with a one-hot
  matmul on the lane axis (a scatter would serialize);
- the last grid step divides by the per-client counts (Eq. 7 mean),
  assembles the (4, lanes) raw feature block, applies Eq. 8 max-scaling
  (external column maxima — the mesh-sharded path's pmax seam — or
  in-kernel masked lane maxima) and runs the shared ``mamdani_lanes``
  inference from ``kernels/fuzzy_eval.py``.

Clients live on the lane axis (``n_clients + 1`` lanes rounded up to a
lane multiple; the ``+ 1`` overflow lane swallows padding samples).
VMEM framing: the fc1 weight block (3136 x 512 fp32 = 6.4 MB) dominates;
``block_s = 64`` keeps the widest activation (64 x 28 x 28 x 32 fp32 =
6.4 MB) at parity with it, ~14 MB total with the smaller stages.

On this CPU container the kernel executes in interpret mode (parity
tests); the fast CPU path is the jnp impl in ``kernels/ops.py``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fuzzy_eval import (LANE, NUM_LEVELS, NUM_OUT, NUM_VARS,
                                      mamdani_lanes, static_rules)

BLOCK_S = 64         # probe samples per grid step (see VMEM framing above)


def _conv_same_gemm(x: jax.Array, wmat: jax.Array, b: jax.Array,
                    k: int) -> jax.Array:
    """SAME stride-1 convolution as an im2col GEMM: x (B, H, W, Cin),
    wmat (k*k*Cin, Cout) — 25 static shifted slices concatenated on the
    channel axis feed one matmul (tap-major, channel-minor rows, i.e.
    ``w.reshape(k*k*Cin, Cout)`` of an HWIO kernel)."""
    bs, h, w, cin = x.shape
    r = k // 2
    xp = jnp.pad(x, ((0, 0), (r, r), (r, r), (0, 0)))
    cols = [xp[:, dy:dy + h, dx:dx + w, :].reshape(bs * h * w, cin)
            for dy in range(k) for dx in range(k)]
    col = jnp.concatenate(cols, axis=1)              # (B*H*W, k*k*Cin)
    return (col @ wmat).reshape(bs, h, w, -1) + b[0]


def _pool2(x: jax.Array) -> jax.Array:
    """2x2/2 max pool as reshape-max (tiles exactly; no reduce_window)."""
    bs, h, w, c = x.shape
    return x.reshape(bs, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def _block_losses(im_ref, lb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                  f1_ref, fb1_ref, f2_ref, fb2_ref, *, img: int,
                  k: int) -> jax.Array:
    """One block's CNN forward + per-sample NLL: (block_s,) losses."""
    bs = im_ref.shape[0]
    x = im_ref[...].reshape(bs, img, img, 1)
    x = _pool2(jnp.maximum(_conv_same_gemm(x, w1_ref[...], b1_ref[...], k),
                           0.0))
    x = _pool2(jnp.maximum(_conv_same_gemm(x, w2_ref[...], b2_ref[...], k),
                           0.0))
    x = x.reshape(bs, -1)
    h = jnp.maximum(x @ f1_ref[...] + fb1_ref[0], 0.0)
    logits = h @ f2_ref[...] + fb2_ref[0]            # (bs, 10)
    zmax = jnp.max(logits, axis=-1)
    logz = zmax + jnp.log(jnp.sum(jnp.exp(logits - zmax[:, None]), axis=-1))
    n_cls = logits.shape[-1]
    onehot = (lb_ref[...][0, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, n_cls), 1)
              ).astype(logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return logz - gold


def _accumulate(acc_ref, losses: jax.Array, seg_ref, lanes: int) -> None:
    """Per-client one-hot loss reduction on the lane axis."""
    onehot = (seg_ref[...][0, :, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, lanes), 1)
              ).astype(jnp.float32)                  # (bs, lanes)
    acc_ref[...] += losses[None, :] @ onehot


def _fused_kernel(im_ref, lb_ref, seg_ref, counts_ref, aux_ref, means_ref,
                  sigmas_ref, centers_ref, colmax_ref, w1_ref, b1_ref,
                  w2_ref, b2_ref, f1_ref, fb1_ref, f2_ref, fb2_ref,
                  lf_ref, ev_ref, acc_ref, *, rule_table: tuple,
                  rule_levels: tuple, n_clients: int, img: int, k: int,
                  external_maxima: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lf_ref[...] = jnp.zeros_like(lf_ref)
        ev_ref[...] = jnp.zeros_like(ev_ref)

    losses = _block_losses(im_ref, lb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                           f1_ref, fb1_ref, f2_ref, fb2_ref, img=img, k=k)
    lanes = acc_ref.shape[1]
    _accumulate(acc_ref, losses, seg_ref, lanes)

    @pl.when(i == pl.num_programs(0) - 1)
    def _finish():
        lf = acc_ref[0, :] / jnp.maximum(counts_ref[0, :], 1.0)
        lf_ref[...] = lf[None, :]
        feats = jnp.concatenate([aux_ref[...], lf[None, :]], axis=0)
        valid = (jax.lax.broadcasted_iota(jnp.int32, (1, lanes), 1)
                 < n_clients)                        # (1, lanes)
        if external_maxima:
            maxima = colmax_ref[...]                 # (V, 1)
        else:                                        # Eq. 8 over the fleet
            maxima = jnp.max(jnp.where(valid, feats, -jnp.inf),
                             axis=1, keepdims=True)
        x = jnp.clip(feats / jnp.maximum(maxima, 1e-9), 0.0, 1.0)
        ev = mamdani_lanes(x, means_ref[...], sigmas_ref[...],
                           centers_ref[...], rule_table, rule_levels)
        ev_ref[...] = jnp.where(valid, ev[None, :], 0.0)


def _loss_kernel(im_ref, lb_ref, seg_ref, counts_ref, w1_ref, b1_ref,
                 w2_ref, b2_ref, f1_ref, fb1_ref, f2_ref, fb2_ref,
                 lf_ref, acc_ref, *, img: int, k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        lf_ref[...] = jnp.zeros_like(lf_ref)

    losses = _block_losses(im_ref, lb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                           f1_ref, fb1_ref, f2_ref, fb2_ref, img=img, k=k)
    _accumulate(acc_ref, losses, seg_ref, acc_ref.shape[1])

    @pl.when(i == pl.num_programs(0) - 1)
    def _finish():
        lf_ref[...] = (acc_ref[...] /
                       jnp.maximum(counts_ref[...], 1.0))


def _lanes(n_clients: int) -> int:
    """Client lanes: n + 1 (overflow lane for padding samples) rounded
    up to a lane multiple."""
    return -(-(n_clients + 1) // LANE) * LANE


def _packed_operands(params, images, labels, seg, counts, n_clients: int,
                     block_s: int):
    """Flatten/pad the packed probe + CNN weights into kernel layout."""
    s = images.shape[0]
    pad = (-s) % block_s
    f32 = jnp.float32
    im = images.reshape(s, -1).astype(f32)
    if pad:
        im = jnp.pad(im, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        seg = jnp.pad(seg, (0, pad), constant_values=n_clients)
    lanes = _lanes(n_clients)
    counts_l = jnp.zeros((1, lanes), f32).at[0, :n_clients].set(
        counts.astype(f32))
    k = params["conv1"]["w"].shape[0]
    img = int(np.sqrt(im.shape[1]))
    weights = []
    for name in ("conv1", "conv2"):
        w = params[name]["w"].astype(f32)
        weights += [w.reshape(-1, w.shape[-1]),
                    params[name]["b"].astype(f32)[None, :]]
    for name in ("fc1", "fc2"):
        weights += [params[name]["w"].astype(f32),
                    params[name]["b"].astype(f32)[None, :]]
    return (im, labels.astype(jnp.int32)[None, :],
            seg.astype(jnp.int32)[None, :], counts_l, weights, lanes,
            img, k, im.shape[0] // block_s)


def _rep(shape):
    return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))


def _weight_specs(weights):
    return [_rep(tuple(w.shape)) for w in weights]


def probe_loss_pallas(params, images: jax.Array, labels: jax.Array,
                      seg: jax.Array, counts: jax.Array, *, n_clients: int,
                      block_s: int = BLOCK_S,
                      interpret: bool = True) -> jax.Array:
    """Eq. 7 packed probe as one kernel launch: (S, 28, 28, 1) samples ->
    (N,) per-client mean losses.  The mesh-sharded prefix calls this per
    shard and psums the result (its collective seam stays outside the
    kernel)."""
    (im, lb, sg, counts_l, weights, lanes, img, k, nb) = _packed_operands(
        params, images, labels, seg, counts, n_clients, block_s)
    out = pl.pallas_call(
        functools.partial(_loss_kernel, img=img, k=k),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_s, img * img), lambda i: (i, 0)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            _rep((1, lanes)),
        ] + _weight_specs(weights),
        out_specs=_rep((1, lanes)),
        out_shape=jax.ShapeDtypeStruct((1, lanes), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, lanes), jnp.float32)],
        interpret=interpret,
    )(im, lb, sg, counts_l, *weights)
    return out[0, :n_clients]


def probe_fuzzy_pallas(params, images: jax.Array, labels: jax.Array,
                       seg: jax.Array, counts: jax.Array, aux: jax.Array,
                       means: jax.Array, sigmas: jax.Array,
                       rule_table: np.ndarray, rule_levels: np.ndarray,
                       level_centers: jax.Array, *, n_clients: int,
                       block_s: int = BLOCK_S, interpret: bool = True,
                       col_maxima: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """The fused fast path: packed probe samples in, per-client raw
    features and Mamdani evaluations out, one launch.

    aux: (N, 3) raw [SQ, TA, CC] columns (LF comes from the probe);
    col_maxima: optional (4,) external Eq. 8 maxima.  Returns
    ``(feats (N, 4), evals (N,))``."""
    (im, lb, sg, counts_l, weights, lanes, img, k, nb) = _packed_operands(
        params, images, labels, seg, counts, n_clients, block_s)
    f32 = jnp.float32
    aux_l = jnp.zeros((3, lanes), f32).at[:, :n_clients].set(
        aux.T.astype(f32))
    external = col_maxima is not None
    colmax = (col_maxima.astype(f32)[:, None] if external
              else jnp.ones((NUM_VARS, 1), f32))
    table, levels = static_rules(rule_table, rule_levels)

    lf, ev = pl.pallas_call(
        functools.partial(_fused_kernel, rule_table=table,
                          rule_levels=levels, n_clients=n_clients, img=img,
                          k=k, external_maxima=external),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_s, img * img), lambda i: (i, 0)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            pl.BlockSpec((1, block_s), lambda i: (0, i)),
            _rep((1, lanes)),
            _rep((3, lanes)),
            _rep((NUM_VARS, NUM_LEVELS)),
            _rep((NUM_VARS, NUM_LEVELS)),
            _rep((1, NUM_OUT)),
            _rep((NUM_VARS, 1)),
        ] + _weight_specs(weights),
        out_specs=[_rep((1, lanes)), _rep((1, lanes))],
        out_shape=[jax.ShapeDtypeStruct((1, lanes), jnp.float32),
                   jax.ShapeDtypeStruct((1, lanes), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, lanes), jnp.float32)],
        interpret=interpret,
    )(im, lb, sg, counts_l, aux_l, means.astype(f32), sigmas.astype(f32),
      level_centers.astype(f32)[None, :], colmax, *weights)
    lf_n = lf[0, :n_clients]
    feats = jnp.concatenate([aux.astype(f32), lf_n[:, None]], axis=1)
    return feats, ev[0, :n_clients]
