"""Jit'd dispatch wrappers over the Pallas kernels and their jnp paths.

Selection order (env ``REPRO_KERNEL_IMPL`` or the ``impl=`` argument):
- ``jnp``     : fast pure-jnp implementation (default on CPU — this
                container); identical math to the oracle, chunked/vmapped.
- ``pallas``  : Pallas kernel, ``interpret=True`` unless on a real TPU.
- ``oracle``  : the naive reference from ``ref.py`` (tests only).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref


def _impl(arg: Optional[str]) -> str:
    return arg or os.environ.get("REPRO_KERNEL_IMPL", "jnp")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------

def wkv6(r, k, v, w, u, s0, impl: Optional[str] = None
         ) -> Tuple[jax.Array, jax.Array]:
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.wkv6 import wkv6_pallas
        return wkv6_pallas(r, k, v, w, u, s0, interpret=_interpret())
    if m == "oracle":
        return kref.wkv6_ref(r, k, v, w, u, s0)
    if m == "scan":
        from repro.models.rwkv6 import wkv6_scan   # per-step (paper-naive)
        return wkv6_scan(r, k, v, w, u, s0)
    # default: chunked matmul formulation (TPU-native; see rwkv6.py)
    from repro.models.rwkv6 import wkv6_chunked
    return wkv6_chunked(r, k, v, w, u, s0)


# --------------------------------------------------------------------------
# Fuzzy evaluation
# --------------------------------------------------------------------------

def fuzzy_eval(x, means, sigmas, rule_table: np.ndarray,
               rule_levels: np.ndarray, level_centers,
               impl: Optional[str] = None,
               normalize: bool = False,
               col_maxima=None) -> jax.Array:
    """``normalize=True`` accepts raw feature columns and applies Eq. 8
    per-column max-scaling inside the kernel (both impls) — the staged
    ``evaluate`` stage feeds raw [SQ, TA, CC, LF].

    ``col_maxima`` (only meaningful with ``normalize=True``) supplies the
    per-column maxima externally instead of computing them over ``x`` —
    the mesh-sharded prefix pmax-reduces the maxima across client
    shards and passes them here, so each shard normalizes against the
    *global* Eq. 8 denominator.  The scaling ops match the jnp/ref
    in-kernel path exactly (``x / maxima``), so results are bitwise-equal
    to it when ``col_maxima`` equals ``x.max(axis=0)`` of the full
    batch; the Pallas kernel normalizes via a reciprocal multiply and
    may differ in the last ulp."""
    if normalize and col_maxima is not None:
        maxima = jnp.maximum(col_maxima, 1e-9)
        x = jnp.clip(x / maxima, 0.0, 1.0)
        normalize = False
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.fuzzy_eval import fuzzy_eval_pallas
        return fuzzy_eval_pallas(x, means, sigmas, rule_table, rule_levels,
                                 level_centers, interpret=_interpret(),
                                 normalize=normalize)
    return kref.fuzzy_eval_ref(x, means, sigmas, rule_table, rule_levels,
                               level_centers, normalize=normalize)


# --------------------------------------------------------------------------
# Fused Eq. 7 probe -> Eq. 8 -> Mamdani evaluation (the selection hot path)
# --------------------------------------------------------------------------

def probe_fuzzy(params, images, labels, seg, counts, aux, means, sigmas,
                rule_table: np.ndarray, rule_levels: np.ndarray,
                level_centers, *, n_clients: int, batch: int = 128,
                impl: Optional[str] = None,
                col_maxima=None) -> Tuple[jax.Array, jax.Array]:
    """The selection prefix's device-resident fast path: packed Eq. 7
    probe samples -> per-client raw features + Mamdani evaluations.

    - ``jnp`` (default on CPU): the chunked packed probe
      (``dataset_loss_packed``) and the reference Mamdani inference fused
      into the caller's jit — one XLA program, no intermediate host or
      HBM round-trips between the stages.
    - ``pallas``: ONE kernel launch (``probe_fuzzy_pallas``): the conv/
      pool/dense probe staged through VMEM, per-client one-hot loss
      reduction on the lane axis, Eq. 8 + 81-rule Mamdani on the final
      grid step.  Interpret mode off-TPU.
    - ``oracle``: the naive unchunked transcription (tests only).

    ``aux``: (N, 3) raw [SQ, TA, CC] columns; ``col_maxima``: optional
    (4,) external Eq. 8 maxima (the mesh-sharded prefix's pmax seam).
    Returns ``(feats (N, 4) raw, evals (N,))``."""
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.probe_fuzzy import probe_fuzzy_pallas
        return probe_fuzzy_pallas(params, images, labels, seg, counts, aux,
                                  means, sigmas, rule_table, rule_levels,
                                  level_centers, n_clients=n_clients,
                                  interpret=_interpret(),
                                  col_maxima=col_maxima)
    if m == "oracle":
        return kref.probe_fuzzy_ref(params, images, labels, seg, counts,
                                    aux, means, sigmas, rule_table,
                                    rule_levels, level_centers,
                                    n_clients=n_clients,
                                    col_maxima=col_maxima)
    from repro.fl.client import dataset_loss_packed
    lf = dataset_loss_packed(params, images, labels, seg, counts,
                             n_clients=n_clients, batch=batch)
    feats = jnp.concatenate([aux, lf[:, None]], axis=1).astype(jnp.float32)
    evals = fuzzy_eval(feats, means, sigmas, rule_table, rule_levels,
                       level_centers, impl="jnp", normalize=True,
                       col_maxima=col_maxima)
    return feats, evals


def probe_loss(params, images, labels, seg, counts, *, n_clients: int,
               batch: int = 128, impl: Optional[str] = None) -> jax.Array:
    """The fused fast path's probe half alone: (N,) per-client Eq. 7 mean
    losses.  The mesh-sharded prefix runs this per shard — the psum that
    merges shards' loss lanes stays outside the kernel."""
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.probe_fuzzy import probe_loss_pallas
        return probe_loss_pallas(params, images, labels, seg, counts,
                                 n_clients=n_clients,
                                 interpret=_interpret())
    if m == "oracle":
        return kref.probe_loss_ref(params, images, labels, seg, counts,
                                   n_clients=n_clients)
    from repro.fl.client import dataset_loss_packed
    return dataset_loss_packed(params, images, labels, seg, counts,
                               n_clients=n_clients, batch=batch)


# --------------------------------------------------------------------------
# Neighbour election
# --------------------------------------------------------------------------

def neighbor_elect(pos, evals, *, comm_range: float, top_m: int,
                   e_tau: float, impl: Optional[str] = None) -> jax.Array:
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.neighbor_elect import neighbor_elect_pallas
        return neighbor_elect_pallas(pos, evals, comm_range=comm_range,
                                     top_m=top_m, e_tau=e_tau,
                                     interpret=_interpret())
    return kref.neighbor_elect_ref(pos, evals, comm_range=comm_range,
                                   top_m=top_m, e_tau=e_tau)


def neighbor_elect_windowed(pos, evals, *, comm_range: float, top_m: int,
                            e_tau: float, window: int,
                            impl: Optional[str] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """O(N*W) windowed election -> ``(mask (N,) int32, overflow ()
    int32)``.  ``overflow == 0`` certifies the mask bit-identical to
    ``neighbor_elect``; callers re-run the dense election otherwise.
    ``pallas`` routes the sorted counting sweep through
    ``windowed_counts_pallas``; ``oracle`` is the naive ref (dense mask +
    rank-distance overflow check, tests only)."""
    m = _impl(impl)
    if m == "oracle":
        return kref.windowed_elect_ref(pos, evals, comm_range=comm_range,
                                       top_m=top_m, e_tau=e_tau,
                                       window=window)
    from repro.core.elect import windowed_elect
    return windowed_elect(pos, evals, comm_range=comm_range, top_m=top_m,
                          e_tau=e_tau, window=window,
                          impl="pallas" if m == "pallas" else "jnp")


# --------------------------------------------------------------------------
# Selective scan (Mamba-1)
# --------------------------------------------------------------------------

def selective_scan(x, dt, bmat, cmat, a, h0, impl: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.selective_scan import selective_scan_pallas
        return selective_scan_pallas(x, dt, bmat, cmat, a, h0,
                                     interpret=_interpret())
    return kref.selective_scan_ref(x, dt, bmat, cmat, a, h0)


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                    prefix_len=0, impl: Optional[str] = None) -> jax.Array:
    """Self-attention layout (q_pos/kv_pos = arange).  The Pallas path is
    the real TPU kernel; the jnp path is the GSPMD-friendly chunked
    softmax in models/attention.py."""
    m = _impl(impl)
    if m == "pallas":
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      prefix_len=prefix_len,
                                      interpret=_interpret())
    from repro.models.attention import flash_attention as flash_jnp
    return flash_jnp(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                     prefix_len=prefix_len)
