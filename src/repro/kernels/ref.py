"""Pure-jnp oracles for every Pallas kernel (the allclose reference).

These are deliberately naive/direct transcriptions of the math — the
kernels and the fast jnp paths are validated against these in
``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# WKV6 (RWKV-6 data-dependent-decay recurrence)
# --------------------------------------------------------------------------

def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, s0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive per-step scan.  r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N).

    y_t = r_t · (S + u ⊙ k_t ⊗ v_t);  S <- diag(w_t)·S + k_t ⊗ v_t.
    """
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = w.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[..., :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(z.swapaxes(0, 1) for z in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), sT


# --------------------------------------------------------------------------
# Fuzzy evaluator (Mamdani, singleton consequents, COG)
# --------------------------------------------------------------------------

def gaussian_membership(x: jax.Array, means: jax.Array,
                        sigmas: jax.Array) -> jax.Array:
    """x: (..., V); means/sigmas: (V, L) -> memberships (..., V, L)."""
    d = x[..., :, None] - means
    return jnp.exp(-0.5 * jnp.square(d / sigmas))


def fuzzy_eval_ref(x: jax.Array, means: jax.Array, sigmas: jax.Array,
                   rule_table: np.ndarray, rule_levels: np.ndarray,
                   level_centers: jax.Array,
                   normalize: bool = False) -> jax.Array:
    """Mamdani inference with min-conjunction, max-aggregation per output
    level, COG over singleton level centers.

    x: (P, V) normalized inputs in [0,1] — or raw features when
    ``normalize=True``, which applies Eq. 8 per-column max-scaling
    (x / max(column), clipped to [0, 1]) before inference;
    means/sigmas: (V, 3) Gaussian membership params;
    rule_table: (R, V) int, linguistic index per variable per rule;
    rule_levels: (R,) int in [0, 9), consequent level per rule;
    level_centers: (9,) COG singleton positions.
    Returns evaluations (P,) in [0, 1]-ish (scale of level_centers).
    """
    if normalize:                                            # Eq. 8
        maxima = jnp.maximum(x.max(axis=0), 1e-9)
        x = jnp.clip(x / maxima, 0.0, 1.0)
    mu = gaussian_membership(x, means, sigmas)               # (P, V, 3)
    p, v, _ = mu.shape
    rt = jnp.asarray(rule_table)                             # (R, V)
    sel = jnp.take_along_axis(
        mu[:, None, :, :],                                   # (P,1,V,3)
        rt[None, :, :, None], axis=3)[..., 0]                # (P,R,V)
    firing = sel.min(axis=-1)                                # (P, R)
    lv = jnp.asarray(rule_levels)                            # (R,)
    onehot = jax.nn.one_hot(lv, 9, dtype=firing.dtype)       # (R, 9)
    beta = (firing[:, :, None] * onehot).max(axis=1)         # (P, 9) max-agg
    num = (beta * level_centers).sum(-1)
    den = jnp.maximum(beta.sum(-1), 1e-9)
    return num / den


# --------------------------------------------------------------------------
# Fused Eq. 7 probe -> Eq. 8 normalize -> Mamdani evaluation
# --------------------------------------------------------------------------

def probe_loss_ref(params, images: jax.Array, labels: jax.Array,
                   seg: jax.Array, counts: jax.Array,
                   n_clients: int) -> jax.Array:
    """Naive Eq. 7 over a packed sample tensor: every per-sample loss in
    one unchunked forward pass, reduced per client with a segment one-hot
    matvec.  images: (S, 28, 28, 1); seg: (S,) client id per sample
    (``n_clients`` marks padding rows); counts: (N,).  Returns (N,) mean
    losses."""
    from repro.models.cnn import cnn_sample_losses
    losses = cnn_sample_losses(params, images, labels)        # (S,)
    onehot = (seg[:, None] == jnp.arange(n_clients + 1)[None, :]
              ).astype(jnp.float32)                           # (S, N+1)
    tot = losses @ onehot
    return tot[:n_clients] / jnp.maximum(counts.astype(jnp.float32), 1.0)


def probe_fuzzy_ref(params, images: jax.Array, labels: jax.Array,
                    seg: jax.Array, counts: jax.Array, aux: jax.Array,
                    means: jax.Array, sigmas: jax.Array,
                    rule_table: np.ndarray, rule_levels: np.ndarray,
                    level_centers: jax.Array, n_clients: int,
                    col_maxima: jax.Array | None = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """The whole selection hot path as one direct transcription: Eq. 7
    packed loss probe -> raw feature assembly -> Eq. 8 per-column
    max-scaling -> Mamdani inference.

    ``aux``: (N, 3) raw [SQ=|D_i|, TA bps, CC=1/C_i] columns; the LF
    column comes from the probe.  ``col_maxima`` (4,) supplies external
    Eq. 8 denominators (the mesh-sharded path pmax-reduces them across
    shards); None computes them over this batch.  Returns
    ``(feats (N, 4) raw, evals (N,))``."""
    lf = probe_loss_ref(params, images, labels, seg, counts, n_clients)
    feats = jnp.concatenate([aux, lf[:, None]], axis=1).astype(jnp.float32)
    if col_maxima is None:
        x = feats
        normalize = True
    else:
        x = jnp.clip(feats / jnp.maximum(col_maxima, 1e-9), 0.0, 1.0)
        normalize = False
    evals = fuzzy_eval_ref(x, means, sigmas, rule_table, rule_levels,
                           level_centers, normalize=normalize)
    return feats, evals


# --------------------------------------------------------------------------
# Neighbour election (distributed client selection, paper Alg. 1)
# --------------------------------------------------------------------------

def neighbor_elect_ref(pos: jax.Array, evals: jax.Array, *,
                       comm_range: float, top_m: int,
                       e_tau: float) -> jax.Array:
    """pos: (N,) 1-D road positions; evals: (N,).

    Vehicle i is selected iff eval_i >= E_tau and eval_i is among the top-m
    evaluations within its DSRC range (ties broken by lower index, matching
    the evaluation-table semantics of §5.3).
    Returns int32 (N,) 0/1.
    """
    d = jnp.abs(pos[:, None] - pos[None, :])                 # (N, N)
    neighbour = d <= comm_range
    valid = neighbour & (evals[None, :] >= e_tau)
    better = (evals[None, :] > evals[:, None]) | (
        (evals[None, :] == evals[:, None])
        & (jnp.arange(pos.shape[0])[None, :] < jnp.arange(pos.shape[0])[:, None]))
    n_better = (valid & better).sum(axis=1)
    selected = (evals >= e_tau) & (n_better < top_m)
    return selected.astype(jnp.int32)


def windowed_elect_ref(pos: jax.Array, evals: jax.Array, *,
                       comm_range: float, top_m: int, e_tau: float,
                       window: int) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the windowed election contract: (mask, overflow).

    The mask is always the exact dense election; ``overflow`` is 1 iff
    some vehicle has a valid in-range neighbour more than ``window``
    position-sorted ranks away — i.e. iff a ``window``-wide sorted sweep
    could not have seen every comparison.  A windowed implementation must
    match the mask whenever *its own* overflow flag is 0, and must flag
    whenever this oracle flags (it may over-flag near float boundaries,
    never under-flag)."""
    n = pos.shape[0]
    mask = neighbor_elect_ref(pos, evals, comm_range=comm_range,
                              top_m=top_m, e_tau=e_tau)
    order = jnp.argsort(pos)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    d = jnp.abs(pos[:, None] - pos[None, :])
    validc = (d <= comm_range) & (evals[None, :] >= e_tau)
    far = jnp.abs(rank[:, None] - rank[None, :]) > window
    overflow = jnp.any(validc & far).astype(jnp.int32)
    return mask, overflow


# --------------------------------------------------------------------------
# Selective scan (Mamba-1)
# --------------------------------------------------------------------------

def selective_scan_ref(x: jax.Array, dt: jax.Array, bmat: jax.Array,
                       cmat: jax.Array, a: jax.Array,
                       h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Naive per-step scan.  x, dt: (B,T,Di); bmat, cmat: (B,T,N);
    a: (Di,N); h0: (B,Di,N).

    h_t = exp(dt_t * a) h_{t-1} + (dt_t * x_t) ⊗ B_t ;  y_t = h_t · C_t.
    """
    f32 = jnp.float32

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None].astype(f32) * a)
        h = da * h + (dt_t * x_t).astype(f32)[..., None] \
            * b_t.astype(f32)[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(f32))
        return h, y

    xs = tuple(z.swapaxes(0, 1) for z in (x, dt, bmat, cmat))
    hT, ys = jax.lax.scan(step, h0.astype(f32), xs)
    return ys.swapaxes(0, 1), hT
