"""Pallas TPU kernel for the WKV6 recurrence (RWKV-6 time mix).

TPU adaptation of the CUDA wkv6 kernel: instead of one warp per (batch,
head) with shared-memory staging, we put the (N, N) fp32 state in VMEM
scratch and stream time in chunks of ``CHUNK`` steps per grid step.  The
grid is (B*H, T/CHUNK); TPU grid execution is sequential with the last
axis innermost, so the state scratch carries across time chunks of the
same (b,h) and is re-initialised when the time index is 0.

Layouts: all time-major per (b,h): r,k,v,w are reshaped to (B*H, T, N)
before the call; N = head size = 64 (half a lane register — acceptable;
the hot loop is VPU element-wise + small outer products).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.scanopt import scan_unroll

CHUNK = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sT_ref, s_scratch, *, unroll: int):
    tc = pl.program_id(1)

    @pl.when(tc == 0)
    def _init():
        s_scratch[...] = s0_ref[0]

    u = u_ref[0]                                   # (N,)

    def step(t, s):
        rt = r_ref[0, t, :]                        # (N,)
        kt = k_ref[0, t, :]
        vt = v_ref[0, t, :]
        wt = w_ref[0, t, :]
        kv = kt[:, None] * vt[None, :]             # (N, N)
        y = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
        y_ref[0, t, :] = y
        return wt[:, None] * s + kv

    # chunk-unrolled per the shared XLA loop policy (repro/scanopt.py):
    # interpret mode executes this loop as an XLA:CPU while (the ~5-10x
    # slow path); on TPU the unroll amortizes loop bookkeeping
    s = jax.lax.fori_loop(0, r_ref.shape[1], step, s_scratch[...],
                          unroll=unroll)
    s_scratch[...] = s

    @pl.when(tc == pl.num_programs(1) - 1)
    def _fin():
        sT_ref[0] = s


@functools.partial(jax.jit, static_argnames=("interpret", "unroll"))
def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, s0: jax.Array, interpret: bool = True,
                unroll: int = 0) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B,T,H,N) — any float dtype; u: (H,N); s0: (B,H,N,N) fp32.

    Returns (y (B,T,H,N) fp32, sT (B,H,N,N) fp32).
    ``interpret=True`` executes the kernel body on CPU (this container);
    on a real TPU pass ``interpret=False``.  ``unroll=0`` applies the
    shared chunk-unroll policy to the in-kernel step loop (math
    unchanged — same steps, same order); pass 1 to force the plain loop
    (the before/after comparison in benchmarks/kernels_bench.py).
    """
    b, t, h, n = r.shape
    bh = b * h
    tm = lambda z: (z.astype(jnp.float32).transpose(0, 2, 1, 3)
                    .reshape(bh, t, n))
    rr, kk, vv, ww = tm(r), tm(k), tm(v), tm(w)
    uu = jnp.broadcast_to(u.astype(jnp.float32), (b, h, n)).reshape(bh, n)
    ss = s0.astype(jnp.float32).reshape(bh, n, n)
    chunk = CHUNK if t % CHUNK == 0 else t
    grid = (bh, t // chunk)
    unroll = unroll or scan_unroll(chunk)

    y, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, unroll=unroll),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # r
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # k
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # v
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # w
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),             # u
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),       # s0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # y
            pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0)),       # sT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu, ss)
    y = y.reshape(b, h, t, n).transpose(0, 2, 1, 3)
    return y, sT.reshape(b, h, n, n)
