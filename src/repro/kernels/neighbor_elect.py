"""Pallas TPU kernel: distributed neighbour election (paper Alg. 1).

Vehicle i becomes a client iff its evaluation clears the threshold E_tau
and fewer than ``top_m`` in-range vehicles have a strictly better
evaluation (index tie-break).  This is an O(N^2) masked-counting problem:
grid tiles of (BLOCK_I, BLOCK_J) compare a block of "my" vehicles against
a block of candidate neighbours; a VMEM scratch accumulates the
better-neighbour counts across the (sequential, innermost) j axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_I = 256
BLOCK_J = 1024


def _pick_blocks(n: int) -> Tuple[int, int, int]:
    """Adaptive (block_i, block_j, padded_n) for the dense O(N^2) sweep.

    Small fleets shrink both tiles to the 128-lane floor instead of
    padding to the full 256/1024 defaults (a 96-vehicle fleet pays one
    128x128 tile, not 256x256); large fleets keep the wide 1024-lane
    candidate tile whenever the padded size divides it."""
    m128 = max(128, -(-n // 128) * 128)
    bi = min(BLOCK_I, m128)
    np_ = -(-n // bi) * bi
    bj = BLOCK_J if np_ % BLOCK_J == 0 else bi
    return bi, bj, np_


def _kernel(pos_i_ref, ev_i_ref, idx_i_ref, pos_j_ref, ev_j_ref, idx_j_ref,
            out_ref, count_ref, *, comm_range: float, top_m: int,
            e_tau: float, n_valid: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    pi = pos_i_ref[0, :]                         # (BI,)
    ei = ev_i_ref[0, :]
    ii = idx_i_ref[0, :]
    pj = pos_j_ref[0, :]                         # (BJ,)
    ej = ev_j_ref[0, :]
    ij = idx_j_ref[0, :]

    d = jnp.abs(pi[:, None] - pj[None, :])       # (BI, BJ)
    valid = (d <= comm_range) & (ej[None, :] >= e_tau) & (ij[None, :] < n_valid)
    better = (ej[None, :] > ei[:, None]) | (
        (ej[None, :] == ei[:, None]) & (ij[None, :] < ii[:, None]))
    count_ref[...] += jnp.sum((valid & better).astype(jnp.int32), axis=1)[None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        sel = (ei >= e_tau) & (count_ref[0, :] < top_m) & (ii < n_valid)
        out_ref[...] = sel.astype(jnp.int32)[None, :]


def neighbor_elect_pallas(pos: jax.Array, evals: jax.Array, *,
                          comm_range: float, top_m: int, e_tau: float,
                          interpret: bool = True) -> jax.Array:
    """pos, evals: (N,) -> selected (N,) int32 (1 = becomes a client)."""
    n = pos.shape[0]
    bi, bj, np_ = _pick_blocks(n)
    # pad with sentinels far away / below threshold
    posp = jnp.pad(pos.astype(jnp.float32), (0, np_ - n),
                   constant_values=1e18)
    evp = jnp.pad(evals.astype(jnp.float32), (0, np_ - n),
                  constant_values=-1e18)
    idx = jnp.arange(np_, dtype=jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, comm_range=float(comm_range),
                          top_m=int(top_m), e_tau=float(e_tau), n_valid=n),
        grid=(np_ // bi, np_ // bj),
        in_specs=[
            pl.BlockSpec((1, bi), lambda i, j: (0, i)),        # pos_i
            pl.BlockSpec((1, bi), lambda i, j: (0, i)),        # ev_i
            pl.BlockSpec((1, bi), lambda i, j: (0, i)),        # idx_i
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # pos_j
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # ev_j
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # idx_j
        ],
        out_specs=pl.BlockSpec((1, bi), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, bi), jnp.int32)],
        interpret=interpret,
    )(posp[None, :], evp[None, :], idx[None, :],
      posp[None, :], evp[None, :], idx[None, :])
    return out[0, :n]


# --------------------------------------------------------------------------
# Windowed (position-sorted) counting: O(N * W) instead of O(N^2)
# --------------------------------------------------------------------------

def _win_kernel(pos_i_ref, ev_i_ref, gid_i_ref, pos_j_ref, ev_j_ref,
                gid_j_ref, out_ref, count_ref, *, comm_range: float,
                e_tau: float, n_valid: int, hops: int, nb: int):
    """Grid (row block i, window offset j): candidate block ``i + j -
    hops`` — at most ``hops`` sorted blocks per side, clamped at the
    array edges (the clamp duplicates an edge block; the ``pl.when``
    skips the duplicate so nothing is double-counted)."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    tgt = i + j - hops

    @pl.when((tgt >= 0) & (tgt < nb))
    def _acc():
        pi = pos_i_ref[0, :]
        ei = ev_i_ref[0, :]
        gi = gid_i_ref[0, :]
        pj = pos_j_ref[0, :]
        ej = ev_j_ref[0, :]
        gj = gid_j_ref[0, :]
        d = jnp.abs(pi[:, None] - pj[None, :])
        ok = (d <= comm_range) & (ej[None, :] >= e_tau) \
            & (gj[None, :] < n_valid)
        better = (ej[None, :] > ei[:, None]) | (
            (ej[None, :] == ei[:, None]) & (gj[None, :] < gi[:, None]))
        count_ref[...] += jnp.sum((ok & better).astype(jnp.int32),
                                  axis=1)[None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        out_ref[...] = count_ref[...]


def windowed_counts_pallas(sp: jax.Array, se: jax.Array, sg: jax.Array, *,
                           comm_range: float, e_tau: float, n_valid: int,
                           window: int, block: int,
                           interpret: bool = True) -> jax.Array:
    """Better-neighbour counts over *position-sorted* (M,) arrays already
    padded to a multiple of ``block`` (sentinels pos=1e18 / ev=-1e18 /
    gid >= ``n_valid``).  Each row block only visits the candidate blocks
    covering ``window`` sorted neighbours per side, so the sweep is
    O(M * (window + block)) — the windowed core of the DCS election."""
    m = sp.shape[0]
    nb = m // block
    hops = -(-int(window) // block)
    row = pl.BlockSpec((1, block), lambda i, j: (0, i))
    cand = pl.BlockSpec((1, block),
                        lambda i, j: (0, jnp.clip(i + j - hops, 0, nb - 1)))
    out = pl.pallas_call(
        functools.partial(_win_kernel, comm_range=float(comm_range),
                          e_tau=float(e_tau), n_valid=int(n_valid),
                          hops=hops, nb=nb),
        grid=(nb, 2 * hops + 1),
        in_specs=[row, row, row, cand, cand, cand],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, block), jnp.int32)],
        interpret=interpret,
    )(sp.astype(jnp.float32)[None, :], se.astype(jnp.float32)[None, :],
      sg.astype(jnp.int32)[None, :], sp.astype(jnp.float32)[None, :],
      se.astype(jnp.float32)[None, :], sg.astype(jnp.int32)[None, :])
    return out[0]
