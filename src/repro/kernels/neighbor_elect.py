"""Pallas TPU kernel: distributed neighbour election (paper Alg. 1).

Vehicle i becomes a client iff its evaluation clears the threshold E_tau
and fewer than ``top_m`` in-range vehicles have a strictly better
evaluation (index tie-break).  This is an O(N^2) masked-counting problem:
grid tiles of (BLOCK_I, BLOCK_J) compare a block of "my" vehicles against
a block of candidate neighbours; a VMEM scratch accumulates the
better-neighbour counts across the (sequential, innermost) j axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_I = 256
BLOCK_J = 1024


def _kernel(pos_i_ref, ev_i_ref, idx_i_ref, pos_j_ref, ev_j_ref, idx_j_ref,
            out_ref, count_ref, *, comm_range: float, top_m: int,
            e_tau: float, n_valid: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    pi = pos_i_ref[0, :]                         # (BI,)
    ei = ev_i_ref[0, :]
    ii = idx_i_ref[0, :]
    pj = pos_j_ref[0, :]                         # (BJ,)
    ej = ev_j_ref[0, :]
    ij = idx_j_ref[0, :]

    d = jnp.abs(pi[:, None] - pj[None, :])       # (BI, BJ)
    valid = (d <= comm_range) & (ej[None, :] >= e_tau) & (ij[None, :] < n_valid)
    better = (ej[None, :] > ei[:, None]) | (
        (ej[None, :] == ei[:, None]) & (ij[None, :] < ii[:, None]))
    count_ref[...] += jnp.sum((valid & better).astype(jnp.int32), axis=1)[None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _fin():
        sel = (ei >= e_tau) & (count_ref[0, :] < top_m) & (ii < n_valid)
        out_ref[...] = sel.astype(jnp.int32)[None, :]


def neighbor_elect_pallas(pos: jax.Array, evals: jax.Array, *,
                          comm_range: float, top_m: int, e_tau: float,
                          interpret: bool = True) -> jax.Array:
    """pos, evals: (N,) -> selected (N,) int32 (1 = becomes a client)."""
    n = pos.shape[0]
    pad = (-n) % BLOCK_I
    bj = BLOCK_J if (n + pad) % BLOCK_J == 0 else BLOCK_I
    padj = (-(n + pad)) % bj
    np_ = n + pad + padj
    # pad with sentinels far away / below threshold
    posp = jnp.pad(pos.astype(jnp.float32), (0, np_ - n),
                   constant_values=1e18)
    evp = jnp.pad(evals.astype(jnp.float32), (0, np_ - n),
                  constant_values=-1e18)
    idx = jnp.arange(np_, dtype=jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, comm_range=float(comm_range),
                          top_m=int(top_m), e_tau=float(e_tau), n_valid=n),
        grid=(np_ // BLOCK_I, np_ // bj),
        in_specs=[
            pl.BlockSpec((1, BLOCK_I), lambda i, j: (0, i)),   # pos_i
            pl.BlockSpec((1, BLOCK_I), lambda i, j: (0, i)),   # ev_i
            pl.BlockSpec((1, BLOCK_I), lambda i, j: (0, i)),   # idx_i
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # pos_j
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # ev_j
            pl.BlockSpec((1, bj), lambda i, j: (0, j)),        # idx_j
        ],
        out_specs=pl.BlockSpec((1, BLOCK_I), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, BLOCK_I), jnp.int32)],
        interpret=interpret,
    )(posp[None, :], evp[None, :], idx[None, :],
      posp[None, :], evp[None, :], idx[None, :])
    return out[0, :n]
