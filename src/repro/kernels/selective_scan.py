"""Pallas TPU kernel: Mamba-1 selective scan (jamba's mamba layers).

TPU adaptation of the CUDA ``selective_scan`` kernel: the CUDA version
keeps per-channel state in registers with one thread block per (batch,
channel-chunk); here the (BLOCK_D, N) state lives in VMEM scratch and the
grid is (B, Di/BLOCK_D, T/CHUNK) with time innermost (sequential), so the
state carries across time chunks of the same (batch, channel-block) and
re-initialises at t == 0.

This addresses the jamba train_4k roofline finding (EXPERIMENTS §Perf):
mamba-1's per-(channel, state) decay cannot be chunked into matmuls the
way WKV6 can (the pairwise decay tensor would be (C, C, Di, N)), so on
TPU the per-step recurrence itself must be kept out of HBM — exactly what
this kernel does and what the pure-jnp path cannot express.

Channels sit on the lane axis (BLOCK_D multiple of 128); the state update
is (BLOCK_D, N) element-wise VPU work per step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.scanopt import scan_unroll

BLOCK_D = 256
CHUNK = 128


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref,
            h_scratch, *, unroll: int):
    tc = pl.program_id(2)

    @pl.when(tc == 0)
    def _init():
        h_scratch[...] = h0_ref[0]

    a = a_ref[...]                                   # (BLOCK_D, N)

    def step(t, h):
        dt_t = dt_ref[0, t, :]                       # (BLOCK_D,)
        x_t = x_ref[0, t, :]
        b_t = b_ref[0, t, :]                         # (N,)
        c_t = c_ref[0, t, :]
        da = jnp.exp(dt_t[:, None] * a)              # (BLOCK_D, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    # chunk-unrolled per the shared XLA loop policy (repro/scanopt.py):
    # interpret mode runs this as an XLA:CPU while loop (the ~5-10x slow
    # path); on TPU the unroll amortizes loop bookkeeping
    h = jax.lax.fori_loop(0, x_ref.shape[1], step, h_scratch[...],
                          unroll=unroll)
    h_scratch[...] = h

    @pl.when(tc == pl.num_programs(2) - 1)
    def _fin():
        hT_ref[0] = h


@functools.partial(jax.jit, static_argnames=("interpret", "unroll"))
def selective_scan_pallas(x: jax.Array, dt: jax.Array, bmat: jax.Array,
                          cmat: jax.Array, a: jax.Array, h0: jax.Array,
                          interpret: bool = True, unroll: int = 0
                          ) -> Tuple[jax.Array, jax.Array]:
    """x, dt: (B, T, Di); bmat, cmat: (B, T, N); a: (Di, N);
    h0: (B, Di, N).  Returns (y (B,T,Di) fp32, hT (B,Di,N) fp32).

    h_t = exp(dt_t * a) h_{t-1} + (dt_t * x_t) B_t ;  y_t = h_t · C_t.
    ``interpret=True`` executes on CPU (this container); pass False on TPU.
    ``unroll=0`` applies the shared chunk-unroll policy to the in-kernel
    time loop; pass 1 to force the plain loop (bench baseline).
    """
    b, t, di = x.shape
    n = bmat.shape[-1]
    bd = min(BLOCK_D, di)
    assert di % bd == 0, (di, bd)
    chunk = CHUNK if t % CHUNK == 0 else t
    f32 = jnp.float32
    grid = (b, di // bd, t // chunk)
    unroll = unroll or scan_unroll(chunk)

    y, hT = pl.pallas_call(
        functools.partial(_kernel, unroll=unroll),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j)),   # x
            pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j)),   # dt
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),    # B
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),    # C
            pl.BlockSpec((bd, n), lambda i, j, k: (j, 0)),             # a
            pl.BlockSpec((1, bd, n), lambda i, j, k: (i, j, 0)),       # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda i, j, k: (i, k, j)),   # y
            pl.BlockSpec((1, bd, n), lambda i, j, k: (i, j, 0)),       # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, di), f32),
            jax.ShapeDtypeStruct((b, di, n), f32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), f32)],
        interpret=interpret,
    )(x.astype(f32), dt.astype(f32), bmat.astype(f32), cmat.astype(f32),
      a.astype(f32), h0.astype(f32))
    return y, hT
