"""Fuzzy evaluator tests: Mamdani properties + hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.fuzzy import FuzzyEvaluator, FuzzyEvaluatorConfig
from repro.kernels import ref as kref


@pytest.fixture(scope="module")
def ev():
    return FuzzyEvaluator()


def test_output_range(ev):
    x = jax.random.uniform(jax.random.PRNGKey(0), (257, 4))
    y = ev.evaluate(x)
    assert y.shape == (257,)
    assert float(y.min()) >= 0.0 and float(y.max()) <= 100.0
    assert not jnp.isnan(y).any()


def test_best_beats_worst(ev):
    x = jnp.array([[1.0, 1.0, 1.0, 1.0],      # all best
                   [0.0, 0.0, 0.0, 0.0],      # all worst
                   [0.5, 0.5, 0.5, 0.5]])
    y = np.asarray(ev.evaluate(x))
    assert y[0] > y[2] > y[1]
    assert y[0] > 80.0 and y[1] < 20.0


def test_level_of_matches_centers(ev):
    y = jnp.array([0.0, 12.5, 58.09, 100.0])
    lv = np.asarray(ev.level_of(y))
    assert lv[0] == 0 and lv[1] == 1 and lv[3] == 8
    assert lv[2] in (4, 5)            # the paper's 58.09 example sits here


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=4, max_size=4),
       st.integers(0, 3), st.floats(0.05, 0.3))
def test_monotone_in_each_variable(x, var, delta):
    """Improving any single input never lowers the evaluation (within
    numerical tolerance) — follows from the monotone rule base and
    shared membership functions."""
    ev = FuzzyEvaluator()
    x = np.asarray(x, np.float32)
    x2 = x.copy()
    x2[var] = min(1.0, x2[var] + delta)
    y = np.asarray(ev.evaluate(jnp.stack([jnp.asarray(x), jnp.asarray(x2)])))
    assert y[1] >= y[0] - 1.5        # tolerance: Gaussian tails overlap


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 97))
def test_batch_consistency(n):
    """Evaluating a batch equals evaluating rows independently."""
    ev = FuzzyEvaluator()
    x = jax.random.uniform(jax.random.PRNGKey(n), (n, 4))
    full = np.asarray(ev.evaluate(x))
    one = np.asarray(ev.evaluate(x[:1]))
    np.testing.assert_allclose(full[0], one[0], rtol=1e-5)


def test_evaluate_raw_folds_eq8(ev):
    """evaluate_raw on raw feature columns equals host-side Eq. 8
    normalization + evaluate — the in-kernel fold (ISSUE 3) must stay
    interchangeable with the two-step path the pipeline replaced."""
    scales = jnp.array([4.5e3, 1.04e7, 1.0, 2.3])   # |D|, bps, 1/C, loss
    raw = jax.random.uniform(jax.random.PRNGKey(3), (33, 4)) * scales
    direct = np.asarray(ev.evaluate_raw(raw))
    normed = jnp.clip(raw / jnp.maximum(raw.max(axis=0), 1e-9), 0.0, 1.0)
    two_step = np.asarray(ev.evaluate(normed))
    np.testing.assert_allclose(direct, two_step, rtol=1e-5, atol=1e-4)


def test_calibration_moves_means():
    ev = FuzzyEvaluator()
    hist = np.random.default_rng(0).beta(2, 5, size=(1000, 4))
    ev.calibrate(hist)
    assert ev.cfg.means.shape == (4, 3)
    assert (np.diff(ev.cfg.means, axis=1) > 0).all()   # pct10 < 50 < 90
    y = ev.evaluate(jnp.asarray(hist[:16], jnp.float32))
    assert not jnp.isnan(y).any()
