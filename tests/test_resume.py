"""Preemption-safe resume (ISSUE 10): kill-at-round-r + resume must
reproduce the uninterrupted trajectory's rows, masks and params
**bit-identically** — for the serial driver, the round-ahead overlapped
scheduler, the event-driven server under churn + weighted staleness +
cadence, the sweep's vmapped seed groups, and a forced 4-device clients
mesh.  Plus the sweep-grid recovery contract: completed groups are
skipped verbatim and the final CSV is byte-identical to an
uninterrupted run's.
"""
import json
import os
import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.launch.sweep import (CSV_COLUMNS, completed_job_rows,
                                parse_csv_rows, rows_to_csv,
                                run_seed_group, sweep)
from repro.train.checkpoint import RoundCheckpointer

REPO = Path(__file__).resolve().parent.parent

N_CLIENTS = 10


def _cfg(scheme: str = "ccs-fuzzy", seed: int = 0, n: int = N_CLIENTS,
         **kw) -> FLSimConfig:
    return FLSimConfig(
        scheme=scheme, n_rounds=4, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=n, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=n, seed=seed), **kw)


def _leaves(sim):
    return [np.asarray(x) for x in jax.tree.leaves(sim.params)]


def _assert_params_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _kill_resume_parity(tmp_path, run=None, overlap=None, rounds=4,
                        kill_after=2):
    """Run uninterrupted; then run ``kill_after`` rounds with a
    checkpointer, throw the process state away (fresh simulation) and
    resume to the end — rows and final params must match exactly."""
    full = FLSimulation(_cfg(), run=run)
    rows_full = full.run(rounds, overlap=overlap)

    ck = RoundCheckpointer(str(tmp_path / "ck"))
    part = FLSimulation(_cfg(), run=run)
    part.run(kill_after, overlap=overlap, checkpointer=ck)

    res = FLSimulation(_cfg(), run=run)
    rows_res = res.run(rounds, overlap=overlap, checkpointer=ck,
                       resume=True)
    assert rows_res == rows_full
    _assert_params_equal(_leaves(full), _leaves(res))
    np.testing.assert_array_equal(np.asarray(full.last_mask),
                                  np.asarray(res.last_mask))
    np.testing.assert_array_equal(full.participation, res.participation)


def test_sync_resume_parity(tmp_path):
    """Serial driver: resume from round 2 of 4 is bit-identical."""
    _kill_resume_parity(tmp_path)


def test_overlap_resume_parity(tmp_path):
    """Round-ahead pipelined scheduler: the dispatch a kill threw away
    is re-issued identically from the restored params."""
    _kill_resume_parity(tmp_path, overlap=True)


def test_event_resume_parity(tmp_path):
    """Event-driven server under churn + weighted staleness + a cadence
    faster than the round period: the pending-tick pool crosses the
    kill point and must be restored exactly."""
    run = RunConfig(server="event", churn_rate=0.3,
                    staleness="weighted", staleness_lambda=1.0,
                    agg_cadence_s=20.0)
    _kill_resume_parity(tmp_path, run=run)


def test_checkpoint_dir_runconfig_path(tmp_path):
    """The --checkpoint-dir/--resume contract through RunConfig alone:
    cadence honoured on disk, resume idempotent at end-of-run."""
    d = str(tmp_path / "ck")
    rows = FLSimulation(
        _cfg(), run=RunConfig(checkpoint_dir=d, checkpoint_every=2)
    ).run(4)
    assert RoundCheckpointer(d).rounds_on_disk() == [1, 3]
    # resume after the final round: nothing to run, rows identical
    again = FLSimulation(
        _cfg(), run=RunConfig(checkpoint_dir=d, checkpoint_every=2,
                              resume=True)).run(4)
    assert again == rows


def test_runconfig_checkpoint_validation():
    with pytest.raises(ValueError, match="checkpoint_every"):
        RunConfig(checkpoint_dir="/tmp/x", checkpoint_every=0).resolved()
    with pytest.raises(ValueError, match="resume"):
        RunConfig(resume=True).resolved()


def test_restore_rejects_mismatched_config():
    """A snapshot from a different seed or fleet size must be refused,
    never silently loaded into the wrong simulation."""
    state = FLSimulation(_cfg(seed=0)).capture_state()
    with pytest.raises(ValueError, match="PRNG base"):
        FLSimulation(_cfg(seed=1)).restore_state(state)
    with pytest.raises(ValueError, match="fleet"):
        FLSimulation(_cfg(n=12)).restore_state(state)


# --------------------------------------------------------------------------
# sweep-grid recovery
# --------------------------------------------------------------------------

def _tiny(scheme, classes, dist, seed):
    return FLSimConfig(
        scheme=scheme, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=classes, seed=seed),
        mobility=MobilityConfig(n_vehicles=10, distribution=dist,
                                seed=seed))


@pytest.mark.slow
def test_run_seed_group_resume_parity(tmp_path):
    """A seed group restarted from its round checkpoints finishes with
    the same rows as the uninterrupted group (vmapped prefix path)."""
    ref = run_seed_group("dcs", 9, "uniform", [0, 1], 3, cfg_fn=_tiny)
    d = str(tmp_path / "grp")
    run_seed_group("dcs", 9, "uniform", [0, 1], 2, cfg_fn=_tiny,
                   checkpoint_dir=d)          # "killed" after round 1
    got = run_seed_group("dcs", 9, "uniform", [0, 1], 3, cfg_fn=_tiny,
                         checkpoint_dir=d, resume=True)
    assert got == ref


@pytest.mark.slow
def test_sweep_resume_skips_completed_byte_identical(tmp_path):
    """Grid recovery: with one job's rows already in the partial CSV,
    resume skips that group, reruns the rest, and the final CSV is
    byte-identical to the uninterrupted run's."""
    grid = dict(schemes=("dcs", "random"), classes_list=(9,),
                distributions=("uniform",), seeds=(0, 1), rounds=2,
                cfg_fn=_tiny)
    ref_csv = rows_to_csv(sweep(**grid))
    partial = [r for r in parse_csv_rows(ref_csv)
               if r["scheme"] == "dcs"]        # job 1 of 2 completed
    out = tmp_path / "sweep.csv"
    out.write_text(rows_to_csv(partial))
    logs = []
    rows = sweep(**grid, out_path=str(out),
                 checkpoint_dir=str(tmp_path / "ck"), resume=True,
                 log=logs.append)
    assert rows_to_csv(rows) == ref_csv
    assert out.read_text() == ref_csv          # incremental write landed
    assert any("skipping completed group" in m for m in logs)


def _fake_row(seed=0, rnd=0, scheme="dcs", **over):
    row = {c: 0.0 for c in CSV_COLUMNS}
    row.update(round=rnd, scheme=scheme, seed=seed, classes_per_client=9,
               distribution="uniform", accuracy=0.5, n_selected=3,
               n_aggregated=3, n_straggler=0, n_active=10,
               rounds_behind_hist="3|0|0")
    row.update(over)
    return row


def test_parse_csv_foreign_header_rejected():
    assert parse_csv_rows("a,b,c\n1,2,3\n") is None
    assert parse_csv_rows("") is None


def test_parse_csv_drops_torn_tail():
    """A torn final line (as left by a non-atomic writer) is dropped
    with a warning; intact rows survive and re-format byte-identically."""
    rows = [_fake_row(seed=s, rnd=r) for s in (0, 1) for r in (0, 1)]
    text = rows_to_csv(rows)
    torn = text[:len(text) - 25]               # cut into the last row
    with pytest.warns(RuntimeWarning, match="torn tail"):
        parsed = parse_csv_rows(torn)
    assert len(parsed) == len(rows) - 1
    # format idempotency: the surviving rows re-emit byte-identically
    assert rows_to_csv(parsed) == rows_to_csv(rows[:-1])


def test_completed_job_rows_requires_full_coverage():
    run = RunConfig().resolved()
    jobs = [(("dcs", 9, "uniform"), run)]
    rows = [_fake_row(seed=s, rnd=r) for s in (0, 1) for r in (0, 1)]
    done = completed_job_rows(rows, jobs, seeds=(0, 1), rounds=2)
    assert len(done) == 1 and len(next(iter(done.values()))) == 4
    # a grown grid (more rounds or seeds) invalidates completion
    assert completed_job_rows(rows, jobs, seeds=(0, 1), rounds=3) == {}
    assert completed_job_rows(rows, jobs, seeds=(0, 1, 2), rounds=2) == {}
    # a shrunk grid must not leak out-of-range rows into the final CSV
    done = completed_job_rows(rows, jobs, seeds=(0, 1), rounds=1)
    assert {r["round"] for r in next(iter(done.values()))} == {0}


# --------------------------------------------------------------------------
# forced 4-device clients mesh
# --------------------------------------------------------------------------

_MESH_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import sys
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding
from repro.train.checkpoint import RoundCheckpointer

ckdir = sys.argv[1]
N = 10                                   # not divisible by 4

def cfg(seed=0):
    return FLSimConfig(
        scheme="dcs", n_rounds=3, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N, seed=seed))

mesh = make_clients_mesh(4)
with mesh, logical_sharding(mesh, DEFAULT_RULES):
    full = FLSimulation(cfg())
    assert full.client_mesh is not None and full.n_shards == 4
    rows_full = full.run(3)

    ck = RoundCheckpointer(ckdir)
    part = FLSimulation(cfg())
    part.run(2, checkpointer=ck)          # killed after round 1

    res = FLSimulation(cfg())
    rows_res = res.run(3, checkpointer=ck, resume=True)
    assert rows_res == rows_full, "resume rows diverge on clients mesh"
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.last_mask),
                                  np.asarray(res.last_mask))
print(json.dumps({"ok": True,
                  "n_sel": int(sum(r["n_selected"] for r in rows_full))}))
"""


@pytest.mark.slow
def test_resume_parity_on_forced_mesh(tmp_path):
    """The same kill-and-resume pin on a forced 4-device clients mesh:
    the restored params re-enter the shard_map'd prefix and the psum'd
    FedAvg bit-identically (subprocess, like tests/test_sharding.py)."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_CHILD, str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1500)
    assert proc.returncode == 0, \
        f"mesh resume parity child failed:\n{proc.stderr[-4000:]}"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["ok"] and data["n_sel"] > 0
