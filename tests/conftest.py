import os

# Tests run on the single real CPU device; only the dry-run forces 512
# placeholder devices (and does so in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# hypothesis fallback (offline containers).
#
# CI installs the real hypothesis via `pip install -e .[test]`; some dev
# containers cannot reach an index, so property tests would fail at
# collection.  This shim provides the small subset of the API the suite
# uses — deterministic pseudo-random examples, no shrinking — and is only
# installed when the real package is absent.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def _floats(lo, hi):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: r.choice(seq))

    def _lists(elem, min_size=0, max_size=10):
        return _Strategy(
            lambda r: [elem.draw(r)
                       for _ in range(r.randint(min_size, max_size))])

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def _given(*strats, **kwstrats):
        def deco(fn):
            # zero-arg wrapper: the example args must not look like
            # pytest fixtures (the real hypothesis does the same)
            def wrapper():
                n = getattr(wrapper, "_fallback_max_examples", 20)
                for ex in range(n):
                    r = random.Random(0xC0FFEE + ex)
                    vals = [s.draw(r) for s in strats]
                    kvals = {k: s.draw(r) for k, s in kwstrats.items()}
                    fn(*vals, **kvals)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__fallback__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
