"""Fused probe->evaluate fast path + round-ahead scheduler (ISSUE 5).

Three layers of parity are pinned:

- kernel: interpret-mode Pallas ``probe_fuzzy_pallas`` vs the jnp fast
  path vs the naive oracle on the same packed inputs — per-client
  losses tight, evaluations within 1e-5 relative;
- pipeline: ``selection_prefix`` with ``fused_probe=True`` (fused op +
  tight probe packing) emits selection masks BIT-IDENTICAL to the
  default staged path, per scheme, across rounds of real training —
  including on forced 4-/8-device client meshes with N % K != 0
  padding (subprocess, like tests/test_sharding.py);
- scheduler: the round-ahead overlapped driver produces rows (and
  masks) bit-identical to the serial driver, single-sim and through the
  sweep's seed-vmapped dispatch.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.core.fuzzy import FuzzyEvaluator
from repro.core.rules import build_rule_table
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.fuzzy_eval import block_p, fuzzy_eval_pallas
from repro.models.cnn import init_cnn

REPO = Path(__file__).resolve().parent.parent

N_CLIENTS = 10
N_ROUNDS = 2


def _cfg(scheme: str, seed: int = 0, **kw) -> FLSimConfig:
    return FLSimConfig(
        scheme=scheme, n_rounds=N_ROUNDS, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N_CLIENTS, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N_CLIENTS, seed=seed), **kw)


# --------------------------------------------------------------------------
# kernel parity
# --------------------------------------------------------------------------

def _packed_fixture():
    rng = np.random.default_rng(0)
    n = 6
    counts = np.array([24, 7, 40, 13, 1, 30])
    s = int(counts.sum())
    ev = FuzzyEvaluator()
    table, levels = build_rule_table()
    return dict(
        n=n,
        images=jnp.asarray(rng.normal(size=(s, 28, 28, 1))
                           .astype(np.float32)),
        labels=jnp.asarray(rng.integers(0, 10, s).astype(np.int32)),
        seg=jnp.asarray(np.repeat(np.arange(n), counts).astype(np.int32)),
        counts=jnp.asarray(counts.astype(np.int32)),
        aux=jnp.asarray(np.abs(rng.normal(size=(n, 3)))
                        .astype(np.float32)) * jnp.asarray([100., 1e6, 1.]),
        params=init_cnn(jax.random.PRNGKey(0), CNN_CFG),
        means=jnp.asarray(ev.cfg.means, jnp.float32),
        sigmas=jnp.asarray(ev.cfg.sigmas, jnp.float32),
        centers=jnp.asarray(ev.level_centers, jnp.float32),
        table=table, levels=levels)


def _probe_fuzzy(fx, impl, **kw):
    return kops.probe_fuzzy(fx["params"], fx["images"], fx["labels"],
                            fx["seg"], fx["counts"], fx["aux"], fx["means"],
                            fx["sigmas"], fx["table"], fx["levels"],
                            fx["centers"], n_clients=fx["n"], batch=32,
                            impl=impl, **kw)


def test_probe_fuzzy_pallas_matches_jnp_and_oracle():
    """ISSUE 5 acceptance: interpret-mode Pallas vs jnp reference within
    1e-5 (relative) on evaluations; raw features tight across impls."""
    fx = _packed_fixture()
    f_jnp, e_jnp = _probe_fuzzy(fx, "jnp")
    f_pal, e_pal = _probe_fuzzy(fx, "pallas")
    f_orc, e_orc = _probe_fuzzy(fx, "oracle")
    np.testing.assert_allclose(np.asarray(e_pal), np.asarray(e_jnp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(e_orc), np.asarray(e_jnp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_pal), np.asarray(f_jnp),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f_orc), np.asarray(f_jnp),
                               rtol=1e-5, atol=1e-6)


def test_probe_fuzzy_external_maxima_matches_in_op():
    """The mesh-sharded seam: passing the batch's own column maxima
    externally must reproduce the in-op Eq. 8 normalization."""
    fx = _packed_fixture()
    feats, e_in = _probe_fuzzy(fx, "jnp")
    cm = jnp.asarray(np.asarray(feats).max(axis=0))
    for impl in ("jnp", "pallas", "oracle"):
        _, e_ext = _probe_fuzzy(fx, impl, col_maxima=cm)
        np.testing.assert_allclose(np.asarray(e_ext), np.asarray(e_in),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"impl={impl}")


def test_probe_loss_impls_agree():
    fx = _packed_fixture()
    args = (fx["params"], fx["images"], fx["labels"], fx["seg"],
            fx["counts"])
    l_jnp = kops.probe_loss(*args, n_clients=fx["n"], batch=32, impl="jnp")
    l_pal = kops.probe_loss(*args, n_clients=fx["n"], impl="pallas")
    l_orc = kops.probe_loss(*args, n_clients=fx["n"], impl="oracle")
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_jnp),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(l_orc), np.asarray(l_jnp),
                               rtol=1e-5, atol=1e-6)


def test_probe_fuzzy_ref_matches_composed_stages():
    """The oracle equals dataset_loss_packed + fuzzy_eval_ref composed —
    the fused op is the same math as the staged path."""
    fx = _packed_fixture()
    lf = kref.probe_loss_ref(fx["params"], fx["images"], fx["labels"],
                             fx["seg"], fx["counts"], n_clients=fx["n"])
    feats = jnp.concatenate([fx["aux"], lf[:, None]], axis=1)
    e_staged = kref.fuzzy_eval_ref(feats, fx["means"], fx["sigmas"],
                                   fx["table"], fx["levels"], fx["centers"],
                                   normalize=True)
    _, e_fused = _probe_fuzzy(fx, "oracle")
    np.testing.assert_allclose(np.asarray(e_fused), np.asarray(e_staged),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# fuzzy_eval block sizing (satellite)
# --------------------------------------------------------------------------

def test_fuzzy_block_adapts_to_small_fleets():
    assert block_p(1) == 128
    assert block_p(96) == 128          # was 1024: a 10.7x dead-lane pad
    assert block_p(129) == 256
    assert block_p(1024) == 1024
    assert block_p(5000) == 1024       # cap holds for big fleets


def test_fuzzy_eval_small_fleet_matches_ref():
    """A 96-client fleet runs in one 128-lane block and still matches
    the reference (padding lanes cannot leak into real ones)."""
    rng = np.random.default_rng(3)
    ev = FuzzyEvaluator()
    table, levels = build_rule_table()
    means = jnp.asarray(ev.cfg.means, jnp.float32)
    sigmas = jnp.asarray(ev.cfg.sigmas, jnp.float32)
    centers = jnp.asarray(ev.level_centers, jnp.float32)
    for p in (5, 96, 200):
        x = jnp.asarray(rng.uniform(0, 1, (p, 4)).astype(np.float32))
        got = fuzzy_eval_pallas(x, means, sigmas, table, levels, centers,
                                interpret=True)
        want = kref.fuzzy_eval_ref(x, means, sigmas, table, levels, centers)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4, err_msg=f"P={p}")


# --------------------------------------------------------------------------
# pipeline parity: fused vs unfused masks, with training in the loop
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["dcs", "ccs-fuzzy", "random"])
def test_fused_prefix_masks_bitwise_vs_unfused(scheme):
    """ISSUE 5 acceptance: selection masks BIT-IDENTICAL fused vs
    unfused through ``selection_prefix``, across rounds with real
    training in between (so round 1 probes evolved params)."""
    ref = FLSimulation(_cfg(scheme), run=RunConfig(fused_probe=False))
    fused = FLSimulation(_cfg(scheme))      # fused is the default now
    assert fused.stage_cfg.fused_probe
    # the tight pack must actually be tighter than the aligned pack
    assert (fused.statics.probe_images.shape[0]
            < ref.statics.probe_images.shape[0])
    for r in range(N_ROUNDS):
        a = jax.device_get(ref.selection_state(r))
        b = jax.device_get(fused.selection_state(r))
        np.testing.assert_array_equal(
            np.asarray(a["mask"]), np.asarray(b["mask"]),
            err_msg=f"{scheme} round {r}: fused mask diverges")
        np.testing.assert_array_equal(np.asarray(a["survivors"]),
                                      np.asarray(b["survivors"]))
        np.testing.assert_allclose(np.asarray(a["evals"]),
                                   np.asarray(b["evals"]),
                                   rtol=1e-4, atol=1e-3)
        ra = ref.finish_round(r, a)
        rb = fused.finish_round(r, b)
        assert abs(ra["accuracy"] - rb["accuracy"]) <= 1e-5


# --------------------------------------------------------------------------
# sharded fused parity (forced 4-/8-device meshes, N % K != 0)
# --------------------------------------------------------------------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding

N = 10                                   # not divisible by 4 or 8

def cfg(scheme, seed=0, **kw):
    return FLSimConfig(
        scheme=scheme, n_rounds=2, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N, seed=seed), **kw)

def run_case(scheme, k, rounds):
    plain = FLSimulation(cfg(scheme),                 # unfused, unsharded
                         run=RunConfig(fused_probe=False))
    fused = FLSimulation(cfg(scheme))                 # fused default
    mesh = make_clients_mesh(k)
    with mesh, logical_sharding(mesh, DEFAULT_RULES):
        sh = FLSimulation(cfg(scheme))
        assert sh.client_mesh is not None and sh.n_shards == k
        n_sel = 0
        for r in range(rounds):
            a = jax.device_get(plain.selection_state(r))
            b = jax.device_get(fused.selection_state(r))
            c = jax.device_get(sh.selection_state(r))
            for tag, s in (("fused", b), ("fused+sharded", c)):
                np.testing.assert_array_equal(
                    np.asarray(a["mask"]), np.asarray(s["mask"]),
                    err_msg=f"{scheme} k={k} round {r}: {tag} mask")
                np.testing.assert_array_equal(np.asarray(a["survivors"]),
                                              np.asarray(s["survivors"]))
                np.testing.assert_allclose(np.asarray(a["evals"]),
                                           np.asarray(s["evals"]),
                                           rtol=1e-4, atol=1e-3)
            ra = plain.finish_round(r, a)
            rb = fused.finish_round(r, b)
            rc = sh.finish_round(r, c)
            assert abs(ra["accuracy"] - rb["accuracy"]) <= 1e-5
            assert abs(ra["accuracy"] - rc["accuracy"]) <= 1e-5
            n_sel += int(np.asarray(c["mask"]).sum())
        return n_sel

out = {}
out["dcs_k4"] = run_case("dcs", 4, rounds=2)
out["dcs_k8"] = run_case("dcs", 8, rounds=1)
out["ccs_fuzzy_k4"] = run_case("ccs-fuzzy", 4, rounds=1)
out["ok"] = True
print(json.dumps(out))
"""


def test_fused_sharded_parity_on_forced_meshes():
    """Fused fast path under 4-/8-device client meshes (tight per-shard
    probe regions, psum/pmax seams outside the fused op): masks
    bit-identical to the unfused single-device prefix; N % K != 0 pads
    dummy clients."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=1500)
    assert proc.returncode == 0, \
        f"fused sharded parity child failed:\n{proc.stderr[-4000:]}"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["ok"]
    assert data["dcs_k4"] > 0 and data["dcs_k8"] > 0


# --------------------------------------------------------------------------
# round-ahead scheduler determinism
# --------------------------------------------------------------------------

def test_overlap_scheduler_matches_serial():
    """The round-ahead driver must be a pure pipelining change: rows
    (accuracy, counts, comm accounting) and per-round masks identical
    to the serial driver."""
    serial = FLSimulation(_cfg("dcs"))
    rows_s, masks_s = [], []
    for r in range(N_ROUNDS):
        rows_s.append(serial.run_round(r))
        masks_s.append(serial.last_mask.copy())

    overlap = FLSimulation(_cfg("dcs"))
    rows_o = overlap.run(N_ROUNDS, overlap=True)
    assert rows_s == rows_o
    np.testing.assert_array_equal(masks_s[-1], overlap.last_mask)


def test_overlap_scheduler_matches_serial_fused():
    """Overlap x fused compose: still bit-identical rows."""
    a = FLSimulation(_cfg("random"))        # fused is the default now
    b = FLSimulation(_cfg("random"))
    assert a.run(N_ROUNDS, overlap=False) == b.run(N_ROUNDS, overlap=True)


def test_sweep_overlap_rows_identical():
    """The sweep's seed-vmapped round-ahead path (donated seed-stacked
    params) reproduces the serial sweep rows exactly."""
    from repro.launch.sweep import run_seed_group

    def tiny_cfg(scheme, classes, dist, seed):
        cfg = _cfg(scheme, seed=seed)
        cfg.mobility = MobilityConfig(n_vehicles=N_CLIENTS,
                                      distribution=dist, seed=seed)
        return cfg

    a = run_seed_group("dcs", 9, "uniform", [0, 1], 2, cfg_fn=tiny_cfg,
                       overlap=False)
    b = run_seed_group("dcs", 9, "uniform", [0, 1], 2, cfg_fn=tiny_cfg,
                       overlap=True)
    assert a == b
