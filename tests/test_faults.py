"""Fault-injection harness (ISSUE 10): the ``REPRO_FAULTS`` plan
grammar, terminal actions (SIGKILL / abrupt exit) in real subprocesses,
a SIGKILL-at-checkpoint + resume end-to-end parity pin, torn-checkpoint
fallback to the last good snapshot, the ``overflow@resume`` behaviour
switch, and the multihost launcher's peer-death reaping / retry /
timeout containment."""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.launch import faults
from repro.launch.faults import (FaultDirective, flip_byte, parse_plan,
                                 truncate_file)
from repro.launch.multihost import retry_with_backoff, spawn_multihost

REPO = Path(__file__).resolve().parent.parent

# --------------------------------------------------------------------------
# plan grammar
# --------------------------------------------------------------------------


def test_parse_plan_grammar():
    plan = parse_plan("sigkill@checkpoint-saved:round=2;"
                      "exit=7@mh-child-start:rank=1;"
                      "overflow@resume")
    assert plan[0] == FaultDirective("sigkill", "checkpoint-saved",
                                     (("round", "2"),))
    assert plan[1].action == "exit" and plan[1].code == 7
    assert plan[1].params == (("rank", "1"),)
    assert plan[2] == FaultDirective("overflow", "resume")
    assert parse_plan("") == [] and parse_plan("  ;  ") == []


def test_parse_plan_rejects_malformed():
    with pytest.raises(ValueError, match="bad fault directive"):
        parse_plan("sigkill-no-event")
    with pytest.raises(ValueError, match="bad fault parameter"):
        parse_plan("sigkill@round-done:novalue")


def test_directive_matching():
    d = FaultDirective("sigkill", "round-done", (("round", "2"),))
    assert d.matches("round-done", {"round": 2})       # str-compared
    assert not d.matches("round-done", {"round": 1})
    assert not d.matches("checkpoint-saved", {"round": 2})
    assert not d.matches("round-done", {})             # param missing


def test_active_and_fire_noop(monkeypatch):
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    faults.fire("round-done", round=0)                 # no plan: no-op
    assert not faults.active("overflow", "resume")
    monkeypatch.setenv(faults.ENV_VAR, "overflow@resume")
    assert faults.active("overflow", "resume")
    assert not faults.active("overflow", "round-done")
    faults.fire("round-done", round=0)     # non-terminal: still a no-op


# --------------------------------------------------------------------------
# terminal actions (subprocess: the test process must survive)
# --------------------------------------------------------------------------

_FIRE = ("from repro.launch.faults import fire\n"
         "fire('round-done', round=2)\n"
         "print('SURVIVED')\n")


def _run_fire(plan):
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           faults.ENV_VAR: plan}
    return subprocess.run([sys.executable, "-c", _FIRE],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=120)


def test_fire_sigkill_matches_params():
    proc = _run_fire("sigkill@round-done:round=2")
    assert proc.returncode == -signal.SIGKILL
    assert "SURVIVED" not in proc.stdout
    assert "injecting sigkill at round-done" in proc.stderr


def test_fire_exit_code():
    proc = _run_fire("exit=7@round-done")
    assert proc.returncode == 7 and "SURVIVED" not in proc.stdout


def test_fire_param_mismatch_survives():
    proc = _run_fire("sigkill@round-done:round=5")
    assert proc.returncode == 0 and "SURVIVED" in proc.stdout


# --------------------------------------------------------------------------
# corruption helpers + CLI
# --------------------------------------------------------------------------

def test_truncate_and_flip(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(bytes(range(16)))
    truncate_file(str(p), 4)
    assert p.read_bytes() == bytes([0, 1, 2, 3])
    flip_byte(str(p), 1)
    assert p.read_bytes() == bytes([0, 0xFE, 2, 3])
    flip_byte(str(p), 1)                               # involution
    assert p.read_bytes() == bytes([0, 1, 2, 3])
    with pytest.raises(ValueError, match="out of range"):
        flip_byte(str(p), 99)


def test_faults_cli(tmp_path):
    p = tmp_path / "f.bin"
    p.write_bytes(b"abcdef")
    assert faults.main(["truncate", str(p), "3"]) == 0
    assert p.read_bytes() == b"abc"
    assert faults.main(["flipbyte", str(p), "0"]) == 0
    assert p.read_bytes()[0] == ord("a") ^ 0xFF
    assert faults.main(["check", "sigkill@round-done"]) == 0
    assert faults.main(["bogus"]) == 2


# --------------------------------------------------------------------------
# FL integration: kill at a checkpoint, resume, fall back past torn
# snapshots, and the overflow@resume behaviour switch
# --------------------------------------------------------------------------


def _cfg(seed=0):
    from repro.fl.mobility import MobilityConfig
    from repro.fl.partition import PartitionConfig
    from repro.fl.rounds import FLSimConfig
    return FLSimConfig(
        scheme="ccs-fuzzy", n_rounds=3, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=10, seed=seed))


_SIM_CHILD = r"""
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import hashlib
import json
import sys
import numpy as np
import jax
jax.config.update("jax_enable_x64", False)
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.train.checkpoint import RoundCheckpointer

ckdir, rounds, resume = sys.argv[1], int(sys.argv[2]), sys.argv[3] == "1"
cfg = FLSimConfig(
    scheme="ccs-fuzzy", n_rounds=rounds, local_epochs=1,
    samples_per_class=260, probe_samples=64, seed=0,
    partition=PartitionConfig(n_clients=10, big_clients=3,
                              big_quantity=120, small_quantity=40,
                              classes_per_client=9, seed=0),
    mobility=MobilityConfig(n_vehicles=10, seed=0))
sim = FLSimulation(cfg)
rows = sim.run(rounds, checkpointer=RoundCheckpointer(ckdir),
               resume=resume)
h = hashlib.sha256()
for leaf in jax.tree.leaves(sim.params):
    h.update(np.asarray(leaf).tobytes())
print(json.dumps({"rows": rows, "params_sha256": h.hexdigest()}))
"""


def _run_sim_child(ckdir, rounds, resume, plan=None):
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    env.pop(faults.ENV_VAR, None)
    if plan:
        env[faults.ENV_VAR] = plan
    return subprocess.run(
        [sys.executable, "-c", _SIM_CHILD, str(ckdir), str(rounds),
         "1" if resume else "0"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1500)


@pytest.mark.slow
def test_sigkill_at_checkpoint_then_resume_parity(tmp_path):
    """The acceptance pin, end to end in real processes: SIGKILL the
    worker the instant round 1's snapshot commits, resume in a fresh
    process, and the surviving trajectory (rows + a params digest) is
    identical to an uninterrupted run's."""
    ref = _run_sim_child(tmp_path / "ref", 3, False)
    assert ref.returncode == 0, ref.stderr[-4000:]
    ref_out = json.loads(ref.stdout.strip().splitlines()[-1])

    killed = _run_sim_child(tmp_path / "ck", 3, False,
                            plan="sigkill@checkpoint-saved:round=1")
    assert killed.returncode == -signal.SIGKILL
    assert "injecting sigkill at checkpoint-saved" in killed.stderr

    resumed = _run_sim_child(tmp_path / "ck", 3, True)
    assert resumed.returncode == 0, resumed.stderr[-4000:]
    res_out = json.loads(resumed.stdout.strip().splitlines()[-1])
    assert res_out == ref_out


def test_torn_checkpoint_falls_back_to_last_good(tmp_path):
    """Corrupting the newest snapshot must cost only the rounds since
    the previous good one — the corrupt snapshot is skipped with a
    warning, never silently loaded, and parity still holds."""
    from repro.fl.rounds import FLSimulation
    from repro.train.checkpoint import (CheckpointCorruptWarning,
                                        RoundCheckpointer)
    rows_full = FLSimulation(_cfg()).run(2)

    ck = RoundCheckpointer(str(tmp_path))
    FLSimulation(_cfg()).run(2, checkpointer=ck)
    flip_byte(os.path.join(ck.path_for(1), "arrays.npz"), 10)

    res = FLSimulation(_cfg())
    with pytest.warns(CheckpointCorruptWarning):
        rows_res = res.run(2, checkpointer=ck, resume=True)
    assert rows_res == rows_full           # round 1 replayed from round 0


def test_overflow_switch_forces_dense_recovery(tmp_path, monkeypatch):
    """``overflow@resume`` clamps the windowed election's bucket
    capacity on restore, so every post-resume round exercises the
    ``elect_overflow`` dense-recovery path — and the rows still match
    the uninterrupted run's (overflow recovery is exact)."""
    from repro.fl.rounds import FLSimulation
    from repro.train.checkpoint import RoundCheckpointer
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    rows_full = FLSimulation(_cfg()).run(2)
    ck = RoundCheckpointer(str(tmp_path))
    FLSimulation(_cfg()).run(1, checkpointer=ck)

    monkeypatch.setenv(faults.ENV_VAR, "overflow@resume")
    res = FLSimulation(_cfg())
    rows_res = res.run(2, checkpointer=ck, resume=True)
    assert res.stage_cfg.elect_capacity == 1
    assert rows_res == rows_full


def test_restore_without_switch_keeps_capacity(tmp_path, monkeypatch):
    from repro.fl.rounds import FLSimulation
    from repro.train.checkpoint import RoundCheckpointer
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    ck = RoundCheckpointer(str(tmp_path))
    sim = FLSimulation(_cfg())
    cap = sim.stage_cfg.elect_capacity
    sim.run(1, checkpointer=ck)
    res = FLSimulation(_cfg())
    res.run(2, checkpointer=ck, resume=True)
    assert res.stage_cfg.elect_capacity == cap


# --------------------------------------------------------------------------
# multihost containment: peer death, reaping, retry, timeout
# --------------------------------------------------------------------------

_FAKE_MH = """\
import os
import signal
import sys
import time

rank = int(sys.argv[sys.argv.index("--_mh-proc-id") + 1])
mode = sys.argv[1]
if mode == "faultfire":
    # the same hook client_mesh_context fires before distributed init
    from repro.launch.faults import fire
    fire("mh-child-start", rank=rank)
if mode == "exit3" and rank == 1:
    sys.exit(3)
if mode == "kill9" and rank == 1:
    os.kill(os.getpid(), signal.SIGKILL)
if mode == "clean":
    sys.exit(0)
time.sleep(120)       # survivors block "in a collective" until reaped
"""


@pytest.fixture()
def fake_mh_module(tmp_path, monkeypatch):
    (tmp_path / "chaos_fake_mh.py").write_text(_FAKE_MH)
    monkeypatch.setenv(
        "PYTHONPATH", os.pathsep.join([str(tmp_path), str(REPO / "src")]))
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    return "chaos_fake_mh"


def test_spawn_reaps_survivors_when_peer_exits(fake_mh_module, capsys):
    """Rank 1 dies with exit code 3 while its peers sleep: the parent
    must name the dead rank, reap the sleepers immediately (not after
    their 120s), and report the failure code."""
    t0 = time.monotonic()
    rc = spawn_multihost(fake_mh_module, ["exit3"], 3)
    elapsed = time.monotonic() - t0
    assert rc == 3
    assert elapsed < 60, f"survivors not reaped promptly ({elapsed:.0f}s)"
    err = capsys.readouterr().err
    assert "rank 1/3 died with exit code 3" in err


def test_spawn_normalizes_signal_death(fake_mh_module, capsys):
    """A SIGKILLed rank reports 137 (128+9) — a negative waitpid code
    must never let max() launder the failure into success."""
    rc = spawn_multihost(fake_mh_module, ["kill9"], 2)
    assert rc == 137
    assert "died with signal 9" in capsys.readouterr().err


def test_spawn_all_clean_is_success(fake_mh_module):
    assert spawn_multihost(fake_mh_module, ["clean"], 2) == 0


def test_spawn_timeout_reaps_everyone(fake_mh_module, capsys):
    t0 = time.monotonic()
    rc = spawn_multihost(fake_mh_module, ["hang"], 2, timeout=3)
    elapsed = time.monotonic() - t0
    assert rc == 124 and elapsed < 60
    assert "exceeded" in capsys.readouterr().err


def test_mh_child_start_fault_kills_one_rank(fake_mh_module, monkeypatch):
    """Plan-driven peer death end to end: children inherit the
    ``REPRO_FAULTS`` plan, rank 1 fires the ``mh-child-start`` hook (the
    one the mesh context announces before distributed init) and dies;
    the parent fails the launch fast instead of hanging the barrier."""
    monkeypatch.setenv(faults.ENV_VAR, "exit=5@mh-child-start:rank=1")
    rc = spawn_multihost(fake_mh_module, ["faultfire"], 2)
    assert rc == 5


def test_retry_with_backoff_recovers_and_reports():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("coordinator not up")
        return "joined"

    assert retry_with_backoff(flaky, attempts=4,
                              base_delay_s=0.01) == "joined"
    assert len(calls) == 3

    def doomed():
        raise OSError("nope")

    with pytest.raises(RuntimeError,
                       match=r"dist init failed after 2 attempts"):
        retry_with_backoff(doomed, attempts=2, base_delay_s=0.01,
                           desc="dist init")
