"""Unit tests for the trip-count-aware HLO cost analyzer — the instrument
behind every roofline number, so it gets its own correctness checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _cost_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(comp.as_text())


def test_dot_flops_counted():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _cost_of(lambda x, y: x @ y, a, b)
    want = 2 * 128 * 64 * 256
    assert want <= c.flops <= want * 1.2, c.flops


def test_while_trip_count_multiplies():
    """A scan of N matmuls must cost ~N x one matmul."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def one(x):
        return x @ x

    def scanned(x):
        def body(h, _):
            return h @ h, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    c1 = _cost_of(one, a)
    c10 = _cost_of(scanned, a)
    assert c10.flops >= 8 * c1.flops, (c1.flops, c10.flops)
    assert c10.flops <= 14 * c1.flops, (c1.flops, c10.flops)


def test_xla_cost_analysis_undercounts_loops():
    """Documents the motivation: XLA's own analysis counts while bodies
    once; ours multiplies by known_trip_count."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x):
        def body(h, _):
            return h @ h, None
        h, _ = jax.lax.scan(body, x, None, length=32)
        return h

    comp = jax.jit(scanned).lower(a).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, list):             # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    xla_flops = float(ca.get("flops", 0.0))
    ours = hlo_cost.analyze(comp.as_text()).flops
    assert ours > 4 * max(xla_flops, 1.0)


def test_hbm_bytes_scale_with_tensor_size():
    small = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    big = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    f = lambda x: jnp.tanh(x) * 2.0 + 1.0
    cs = _cost_of(f, small)
    cb = _cost_of(f, big)
    assert cb.hbm_bytes > 30 * cs.hbm_bytes


def test_shape_parser():
    assert hlo_cost._bytes_of("f32[2,3]{1,0}") == 24
    assert hlo_cost._bytes_of("(bf16[4,4], s32[2])") == 32 + 8
    assert hlo_cost._bytes_of("pred[8]") == 8
    assert hlo_cost._bytes_of("token[]") == 0
