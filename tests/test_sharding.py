"""Mesh-sharded client axis (ISSUE 4 acceptance).

The heavy parity checks run in a subprocess with 8 forced CPU host
devices (the device count is fixed at jax backend init, so it cannot be
raised inside an already-running pytest process): on 4- and 8-device
client meshes the shard_map'd ``selection_prefix_sharded`` must emit
selection masks *bit-identical* to the single-device staged pipeline,
and a round completed through the sharded grouped trainer must match
the unsharded global params within 1e-5 — including an
N-not-divisible-by-mesh padding case and an empty-survivor round.

The in-process tests cover the host-side satellite surface: strict /
logged ``resolve_pspec``, the clients-mesh constructors, the launcher
mesh-spec parsing, sharded cohort bucketing and the psum'd FedAvg.
"""
import json
import logging
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.fl import pipeline
from repro.fl.aggregation import fedavg_masked, fedavg_sums
from repro.launch.mesh import (client_mesh_context, make_clients_mesh,
                               make_debug_mesh, parse_mesh_spec)
from repro.sharding.api import resolve_pspec, sweep_devices

REPO = Path(__file__).resolve().parent.parent

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding, \
    sweep_devices

N = 10                                   # not divisible by 4 or 8:
                                         # every mesh pads dummy clients

def cfg(scheme, seed=0, **kw):
    return FLSimConfig(
        scheme=scheme, n_rounds=2, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N, seed=seed), **kw)

def leaves(p):
    return [np.asarray(x) for x in jax.tree.leaves(p)]

def run_case(scheme, k, rounds, **kw):
    ref = FLSimulation(cfg(scheme, **kw))
    mesh = make_clients_mesh(k)
    with mesh, logical_sharding(mesh, DEFAULT_RULES):
        assert len(sweep_devices()) == 1        # one placement domain
        sh = FLSimulation(cfg(scheme, **kw))
        assert sh.client_mesh is not None and sh.n_shards == k
        n_sel = 0
        for r in range(rounds):
            a = jax.device_get(ref.selection_state(r))
            b = jax.device_get(sh.selection_state(r))
            np.testing.assert_array_equal(
                np.asarray(a["mask"]), np.asarray(b["mask"]),
                err_msg=f"{scheme} k={k} round {r}: masks diverge")
            np.testing.assert_array_equal(np.asarray(a["survivors"]),
                                          np.asarray(b["survivors"]))
            np.testing.assert_allclose(np.asarray(a["evals"]),
                                       np.asarray(b["evals"]),
                                       rtol=1e-4, atol=1e-3)
            assert int(a["n_straggler"]) == int(b["n_straggler"])
            assert int(a["n_selected"]) == int(b["n_selected"])
            ra = ref.finish_round(r, a)
            rb = sh.finish_round(r, b)
            for la, lb in zip(leaves(ref.params), leaves(sh.params)):
                np.testing.assert_allclose(
                    la, lb, atol=1e-5,
                    err_msg=f"{scheme} k={k} round {r}: params diverge")
            assert abs(ra["accuracy"] - rb["accuracy"]) <= 1e-5
            n_sel += int(b["n_selected"])
        return n_sel

def run_seeds_case(k):
    # the seed-vmapped prefix, sharded vs unsharded on identical inputs
    import jax.numpy as jnp
    from repro.fl import pipeline
    mesh = make_clients_mesh(k)
    with mesh, logical_sharding(mesh, DEFAULT_RULES):
        sims = [FLSimulation(cfg("dcs")), FLSimulation(cfg("dcs",
                                                           seed=1))]
        st = pipeline.stack_statics([s.statics for s in sims])
        params = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[s.params for s in sims])
        sel = jnp.stack([s.key for s in sims])
        net = jnp.stack([s.net_key for s in sims])
        cfg0 = sims[0].stage_cfg
        a = jax.device_get(pipeline.selection_prefix_seeds(
            st, params, jnp.int32(0), sel, net, cfg=cfg0))
        b = jax.device_get(pipeline.selection_prefix_seeds_sharded(
            st, params, jnp.int32(0), sel, net, cfg=cfg0, mesh=mesh))
        np.testing.assert_array_equal(np.asarray(a["mask"]),
                                      np.asarray(b["mask"]))
        np.testing.assert_array_equal(np.asarray(a["survivors"]),
                                      np.asarray(b["survivors"]))
        np.testing.assert_allclose(np.asarray(a["evals"]),
                                   np.asarray(b["evals"]),
                                   rtol=1e-4, atol=1e-3)
        return int(np.asarray(b["mask"]).sum())

out = {}
out["dcs_k4"] = run_case("dcs", 4, rounds=2)
out["dcs_k8"] = run_case("dcs", 8, rounds=1)
out["random_k4"] = run_case("random", 4, rounds=1)
out["ccs_fuzzy_k8"] = run_case("ccs-fuzzy", 8, rounds=1)
out["seeds_k4"] = run_seeds_case(4)
# empty-survivor round: nobody clears E_tau, both paths no-op broadcast
assert run_case("dcs", 4, rounds=1, e_tau=1e9) == 0
out["ok"] = True
print(json.dumps(out))
"""


def test_sharded_parity_on_forced_4_and_8_device_mesh():
    """ISSUE 4 acceptance: bit-identical masks + <=1e-5 params on 4- and
    8-device CPU client meshes, with client padding and an empty round."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=1500)
    assert proc.returncode == 0, \
        f"sharded parity child failed:\n{proc.stderr[-4000:]}"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["ok"]
    # the sharded rounds actually selected clients (non-degenerate)
    assert data["dcs_k4"] > 0 and data["dcs_k8"] > 0


# -- in-process satellite coverage ------------------------------------------

def _mesh1(axis="clients"):
    return Mesh(np.asarray(jax.devices()[:1]), (axis,))


def test_resolve_pspec_require_raises_on_indivisible():
    mesh = _mesh1()
    with pytest.raises(ValueError, match="clients"):
        resolve_pspec(mesh, {"clients": "clients"}, ("clients",), (10,),
                      require=("clients",))


def test_resolve_pspec_require_raises_without_rule():
    mesh = _mesh1()
    with pytest.raises(ValueError, match="no rule"):
        resolve_pspec(mesh, {}, ("clients",), (8,), require=("clients",))


def test_resolve_pspec_warns_on_nondivisible_drop(caplog):
    mesh = _mesh1("data")
    # 'data' has size 1 here, so force the non-divisible branch with a
    # fake 2-extent via a 2-device mesh if available, else skip
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices for a non-divisible drop")
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    with caplog.at_level(logging.WARNING, logger="repro.sharding.api"):
        spec = resolve_pspec(mesh, {"batch": "data"}, ("batch",), (7,))
    assert spec == P(None)
    assert any("batch" in rec.message for rec in caplog.records)


def test_resolve_pspec_divisible_still_shards():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("clients",))
    spec = resolve_pspec(mesh, {"clients": "clients"}, ("clients", None),
                         (8, 3), require=("clients",))
    assert spec == P("clients", None)


def test_make_debug_mesh_raises_value_error():
    with pytest.raises(ValueError, match="not divisible"):
        make_debug_mesh(n_devices=1, model=3)


def test_make_clients_mesh_too_many_devices():
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_clients_mesh(len(jax.devices()) + 1)


def test_make_clients_mesh_axis():
    mesh = make_clients_mesh(1)
    assert dict(mesh.shape) == {"clients": 1}


def test_parse_mesh_spec():
    assert parse_mesh_spec("clients=8") == {"clients": 8}
    with pytest.raises(ValueError):
        parse_mesh_spec("clients")
    with pytest.raises(ValueError):
        parse_mesh_spec("clients=x")


def test_client_mesh_context_rejects_unknown_axis():
    with pytest.raises(ValueError, match="unknown mesh axes"):
        with client_mesh_context("model=2"):
            pass


def test_client_mesh_context_none_is_noop():
    with client_mesh_context(None) as mesh:
        assert mesh is None
    assert pipeline.active_client_mesh() is None


def test_sweep_devices_without_mesh_lists_devices():
    assert len(sweep_devices()) == len(jax.devices())


def test_cohort_bucket_sharded():
    assert pipeline.cohort_bucket_sharded(3, 1) == 4   # == cohort_bucket
    assert pipeline.cohort_bucket_sharded(1, 4) == 4   # floor 2, pad to 4
    assert pipeline.cohort_bucket_sharded(5, 4) == 8
    assert pipeline.cohort_bucket_sharded(5, 8) == 8
    assert pipeline.pad_to_shards(10, 4) == 12


def test_fedavg_masked_axis_name_matches_unsharded():
    """The psum'd FedAvg (shard_map over a clients mesh) equals the
    plain masked FedAvg."""
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
               "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    weights = jnp.asarray([120.0, 40.0, 0.0, 40.0])
    mesh = _mesh1()
    sharded = shard_map(
        lambda s, w: fedavg_masked(s, w, axis_name="clients"), mesh,
        in_specs=(P("clients"), P("clients")), out_specs=P(),
        check_rep=False)
    got = sharded(stacked, weights)
    want = fedavg_masked(stacked, weights)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_fedavg_sums_matches_masked():
    rng = np.random.default_rng(1)
    stacked = {"w": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))}
    weights = jnp.asarray([10.0, 0.0, 30.0])
    num, den = fedavg_sums(stacked, weights)
    want = fedavg_masked(stacked, weights)
    np.testing.assert_allclose(np.asarray(num["w"]) / float(den),
                               np.asarray(want["w"]), rtol=1e-6)
