"""Event-driven streaming fleet (ISSUE 6): sync bit-parity, churn and
staleness edge cases, the scheme registry, and the RunConfig surface.

Parity pins (acceptance): with churn disabled, staleness "drop" and the
cadence at the round period, the event-driven server reproduces the
serial driver's rows AND final params **bit-identically** — on a single
device, through the sweep's seed-vmapped dispatch, and on a forced
4-device clients mesh (subprocess, like tests/test_sharding.py).

Edge cases (ISSUE 6 satellites): an all-departed round is a no-op
broadcast; when every survivor straggles, aggregation waits for a later
cadence tick; a client departing coverage mid-training loses its pending
update; ``staleness_weight`` is property-tested for monotonicity.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fl import schemes
from repro.fl.async_server import EventDrivenServer
from repro.fl.mobility import MobilityConfig, coverage_active
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.fl.schemes import get_scheme, register_scheme, scheme_names
from repro.fl.timing import staleness_weight

REPO = Path(__file__).resolve().parent.parent

N_CLIENTS = 10
N_ROUNDS = 3


def _cfg(scheme: str = "ccs-fuzzy", seed: int = 0, **kw) -> FLSimConfig:
    return FLSimConfig(
        scheme=scheme, n_rounds=N_ROUNDS, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N_CLIENTS, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N_CLIENTS, seed=seed), **kw)


def _leaves(sim):
    return [np.asarray(x).copy() for x in jax.tree.leaves(sim.params)]


def _assert_params_equal(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, np.asarray(y))


# --------------------------------------------------------------------------
# sync parity: the degenerate event server IS the round barrier
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["dcs", "ccs-fuzzy"])
def test_event_server_sync_parity_rows_and_params(scheme):
    """ISSUE 6 acceptance: churn off + staleness drop + cadence at the
    round period -> the event-driven server reproduces the serial
    driver's rows and final params bit-identically."""
    sync = FLSimulation(_cfg(scheme))
    event = FLSimulation(_cfg(scheme), run=RunConfig(server="event"))
    assert EventDrivenServer(event).sync_equivalent
    rows_s = sync.run(N_ROUNDS)
    rows_e = event.run(N_ROUNDS)
    assert rows_s == rows_e
    _assert_params_equal(_leaves(sync), jax.tree.leaves(event.params))


def test_event_server_sync_parity_through_sweep():
    """The sweep's seed-vmapped dispatch drives the event server
    through the same finish_round seam: rows identical to the sync
    sweep (the CSV bit-parity pin)."""
    from repro.launch.sweep import run_seed_group

    def tiny_cfg(scheme, classes, dist, seed):
        cfg = _cfg(scheme, seed=seed)
        cfg.mobility = MobilityConfig(n_vehicles=N_CLIENTS,
                                      distribution=dist, seed=seed)
        return cfg

    a = run_seed_group("dcs", 9, "uniform", [0, 1], 2, cfg_fn=tiny_cfg)
    b = run_seed_group("dcs", 9, "uniform", [0, 1], 2, cfg_fn=tiny_cfg,
                       run=RunConfig(server="event"))
    assert a == b


_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding

N = 10                                   # not divisible by 4

def cfg(seed=0):
    return FLSimConfig(
        scheme="dcs", n_rounds=2, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N, seed=seed))

mesh = make_clients_mesh(4)
with mesh, logical_sharding(mesh, DEFAULT_RULES):
    sync = FLSimulation(cfg())
    event = FLSimulation(cfg(), run=RunConfig(server="event"))
    assert sync.client_mesh is not None and sync.n_shards == 4
    rows_s = sync.run(2)
    rows_e = event.run(2)
    assert rows_s == rows_e, "event rows diverge on the clients mesh"
    for a, b in zip(jax.tree.leaves(sync.params),
                    jax.tree.leaves(event.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print(json.dumps({"ok": True, "n_sel": int(sum(r["n_selected"]
                                               for r in rows_s))}))
"""


def test_event_server_sync_parity_on_forced_mesh():
    """Same pin on a forced 4-device clients mesh (N % 4 != 0 padding):
    the event server's delegation must preserve the sharded trainer's
    psum'd FedAvg bit-for-bit."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=1500)
    assert proc.returncode == 0, \
        f"event mesh parity child failed:\n{proc.stderr[-4000:]}"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["ok"] and data["n_sel"] > 0


# --------------------------------------------------------------------------
# churn edge cases
# --------------------------------------------------------------------------

def test_coverage_active_window():
    pos = np.array([0.0, 400.0, 800.0, 999.0])
    got = np.asarray(coverage_active(jnp.asarray(pos), road_length_m=1000.0,
                                     churn_rate=0.2))
    np.testing.assert_array_equal(got, [True, True, False, False])
    assert np.asarray(coverage_active(jnp.asarray(pos),
                                      road_length_m=1000.0,
                                      churn_rate=0.0)).all()


def test_all_departed_round_is_noop_broadcast():
    """churn_rate=1.0 empties the coverage window: nobody probes, nobody
    is selected, and the global model broadcast is a bit-exact no-op."""
    sim = FLSimulation(_cfg(), run=RunConfig(churn_rate=1.0))
    before = _leaves(sim)
    rows = sim.run(2)
    for row in rows:
        assert row["n_active"] == 0
        assert row["n_selected"] == 0
        assert row["n_aggregated"] == 0
    _assert_params_equal(before, jax.tree.leaves(sim.params))


def test_all_survivor_stragglers_wait_for_cadence_tick():
    """A deadline below every client's completion time makes the whole
    cohort stragglers: weighted mode still trains them, but their
    updates only land at a later cadence tick — round 0 aggregates
    nothing (params bit-unchanged), a later round folds them in with a
    discounted weight."""
    probe = FLSimulation(_cfg())
    host = jax.device_get(probe.selection_state(0))
    sel = np.asarray(host["mask"]) > 0
    assert sel.any()
    dur = np.asarray(host["t_done"], np.float64)[sel]   # t_s = 0 at r=0
    period = 0.9 * float(dur.min())                     # all miss Eq. 6

    sim = FLSimulation(_cfg(deadline_s=period),
                       run=RunConfig(staleness="weighted",
                                     staleness_lambda=1.0))
    srv = EventDrivenServer(sim)
    before = _leaves(sim)
    row0 = srv.finish_round(0, srv.selection_state(0))
    assert row0["n_selected"] > 0
    assert row0["n_straggler"] == row0["n_selected"]
    assert row0["n_aggregated"] == 0
    _assert_params_equal(before, jax.tree.leaves(sim.params))

    n_rounds = int(np.ceil(dur.max() / period)) + 2
    rows = [srv.finish_round(r, srv.selection_state(r))
            for r in range(1, n_rounds)]
    landed = [r for r in rows if r["n_aggregated"] > 0]
    assert landed, "straggler updates never landed at a cadence tick"
    assert any(r["stale_frac"] > 0.0 for r in landed)
    for r in landed:
        if r["stale_frac"] > 0.0:       # a stale update is discounted
            assert r["n_effective"] < r["n_aggregated"]


def test_departing_mid_training_drops_pending_update():
    """A client out of coverage at its own upload-completion instant
    loses the update: with every ``alive_at_done`` forced False the
    dispatch enqueues nothing and the global model stays bit-exact."""
    sim = FLSimulation(_cfg(), run=RunConfig(churn_rate=0.2,
                                             staleness="weighted",
                                             staleness_lambda=0.5))
    srv = EventDrivenServer(sim)
    host = jax.device_get(srv.selection_state(0))
    host = {k: np.asarray(v) for k, v in host.items()}
    assert (np.asarray(host["mask"]) > 0).any()
    host["alive_at_done"] = np.zeros(N_CLIENTS, bool)
    before = _leaves(sim)
    srv._dispatch_training(0, host)
    assert not srv._pending
    assert srv._stats[0]["n_agg"] == 0
    _assert_params_equal(before, jax.tree.leaves(sim.params))


# --------------------------------------------------------------------------
# staleness weight (property)
# --------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 10.0), st.integers(0, 30), st.integers(0, 30))
def test_staleness_weight_monotone(lam, d1, d2):
    """1/(1 + lambda*delay): in (0, 1], exactly 1 when fresh or when
    lambda is 0, and non-increasing in the delay."""
    lo, hi = sorted((d1, d2))
    w_lo, w_hi = staleness_weight(lam, lo), staleness_weight(lam, hi)
    assert 0.0 < w_hi <= w_lo <= 1.0
    assert staleness_weight(lam, 0) == 1.0
    assert staleness_weight(0.0, hi) == 1.0
    if lam > 0 and hi > lo:
        assert w_hi < w_lo


def test_staleness_weight_rejects_negative():
    with pytest.raises(ValueError):
        staleness_weight(-0.5, 1)
    with pytest.raises(ValueError):
        staleness_weight(1.0, -1)


# --------------------------------------------------------------------------
# scheme registry
# --------------------------------------------------------------------------

def test_unknown_scheme_raises_with_registered_list():
    with pytest.raises(ValueError, match=r"registered: .*dcs"):
        get_scheme("fedprox")
    with pytest.raises(ValueError, match="unknown selection scheme"):
        FLSimulation(_cfg(scheme="fedprox"))


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_scheme("dcs", lambda cfg, pos, evals, key: evals)
    assert get_scheme("dcs").overhead_key == "dcs"   # builtin untouched


def test_custom_scheme_runs_through_simulation():
    """A scheme registered at runtime drives a full round (the registry
    is the only coupling point between pipeline and scheme)."""
    def first_k(cfg, pos, evals, sel_key):
        return (jnp.arange(cfg.n_clients)
                < cfg.n_clients_central).astype(jnp.int32)

    register_scheme("first-k", first_k, overhead_key="cfl")
    try:
        assert "first-k" in scheme_names()
        sim = FLSimulation(_cfg(scheme="first-k"))
        row = sim.run_round(0)
        assert row["n_selected"] == sim.stage_cfg.n_clients_central
        picked = np.where(sim.last_mask > 0)[0]
        assert picked.max() < sim.stage_cfg.n_clients_central
    finally:
        schemes._REGISTRY.pop("first-k", None)


# --------------------------------------------------------------------------
# RunConfig surface + deprecation shim
# --------------------------------------------------------------------------

def test_runconfig_promotes_and_validates():
    assert RunConfig().resolved().server == "sync"
    assert RunConfig(churn_rate=0.3).resolved().server == "event"
    assert RunConfig(staleness="weighted").resolved().server == "event"
    assert RunConfig(agg_cadence_s=5.0).resolved().server == "event"
    with pytest.raises(ValueError):
        RunConfig(churn_rate=1.5).resolved()
    with pytest.raises(ValueError):
        RunConfig(staleness="sometimes").resolved()
    with pytest.raises(ValueError):
        RunConfig(agg_cadence_s=0.0).resolved()
    with pytest.raises(ValueError):      # weighted needs the batched engine
        RunConfig(staleness="weighted", engine="loop").resolved()


def test_deprecated_sim_kwargs_warn_but_work():
    """FLSimConfig.engine/fused_probe/overlap_rounds still work for one
    release: a DeprecationWarning fires and the value lands on the
    resolved RunConfig."""
    with pytest.warns(DeprecationWarning, match="FLSimConfig.engine"):
        sim = FLSimulation(_cfg(engine="loop"))
    assert sim.run_cfg.engine == "loop"
    with pytest.warns(DeprecationWarning, match="fused_probe"):
        sim = FLSimulation(_cfg(fused_probe=False))
    assert not sim.run_cfg.fused_probe
    assert not sim.stage_cfg.fused_probe
    with pytest.warns(DeprecationWarning, match="overlap_rounds"):
        sim = FLSimulation(_cfg(overlap_rounds=False))
    assert not sim.run_cfg.overlap_rounds


def test_runconfig_from_args_compat_flags():
    import argparse

    from repro.fl.runconfig import add_run_arguments

    ap = argparse.ArgumentParser()
    add_run_arguments(ap)
    run = RunConfig.from_args(ap.parse_args([]))
    assert run.fused_probe and run.overlap_rounds and run.server == "sync"
    run = RunConfig.from_args(ap.parse_args(
        ["--compat-aligned-pack", "--no-overlap-rounds"]))
    assert not run.fused_probe and not run.overlap_rounds
    run = RunConfig.from_args(ap.parse_args(
        ["--churn-rate", "0.3", "--staleness", "weighted",
         "--staleness-lambda", "1.5", "--agg-cadence", "0"]))
    assert run.server == "event" and run.agg_cadence_s is None
    assert run.churn_rate == 0.3 and run.staleness_lambda == 1.5


# --------------------------------------------------------------------------
# full event fleet smoke (churn x weighted staleness x sub-round cadence)
# --------------------------------------------------------------------------

def test_event_fleet_smoke_deterministic():
    """Churn + weighted staleness + a sub-round cadence: rows stay
    internally consistent (histogram sums to the aggregate count, the
    effective cohort never exceeds it) and the whole run is
    deterministic across two fresh simulations."""
    run = RunConfig(churn_rate=0.3, staleness="weighted",
                    staleness_lambda=1.0, agg_cadence_s=30.0)

    def go():
        sim = FLSimulation(_cfg(), run=run)
        return sim.run(N_ROUNDS)

    rows = go()
    for row in rows:
        assert 0 <= row["n_active"] <= N_CLIENTS
        assert 0.0 <= row["stale_frac"] <= 1.0
        hist = [int(h) for h in row["rounds_behind_hist"].split("/")]
        assert len(hist) == 4 and sum(hist) == row["n_aggregated"]
        assert row["n_effective"] <= row["n_aggregated"] + 1e-9
    assert any(row["n_active"] < N_CLIENTS for row in rows)
    assert rows == go()
