"""Communication-overhead model vs the paper's own published numbers
(§4.2 / Fig. 2): 22.5 GB at tau=1s, 0.41 GB upload, crossings at ~52 s and
~15 s — these are *the paper's claims*, so exact-value tests."""
import numpy as np
import pytest

from repro.core.overhead import (GBoardParams, IoVParams, accumulated_time_s,
                                 crossing_interval_s, fig2_curves,
                                 fig9_curves, model_upload_bytes,
                                 state_maintenance_bytes)


def test_fig2_state_bytes_at_1s():
    p = GBoardParams()
    c = state_maintenance_bytes(p.n_participants, p.state_bytes_cfl,
                                p.round_period_s, 1.0)
    assert c == pytest.approx(22.5e9, rel=0.05)          # paper: 22.5 GB


def test_fig2_upload_bytes():
    p = GBoardParams()
    up = model_upload_bytes(p.clients_per_round, p.model_bytes)
    assert up == pytest.approx(0.42e9, rel=0.03)         # paper: 0.41 GB


def test_fig2_crossings():
    p = GBoardParams()
    t_cfl = crossing_interval_s(p.n_participants, p.state_bytes_cfl,
                                p.round_period_s, p.clients_per_round,
                                p.model_bytes)
    t_fuz = crossing_interval_s(p.n_participants, p.state_bytes_ccs_fuzzy,
                                p.round_period_s, p.clients_per_round,
                                p.model_bytes)
    # paper: curves cross the upload line at 52 s and 15 s
    assert t_cfl == pytest.approx(52.0, abs=2.0)
    assert t_fuz == pytest.approx(15.0, abs=1.5)


def test_fig2_monotone_decreasing():
    iv = np.linspace(1, 100, 50)
    c = fig2_curves(iv)
    assert (np.diff(c["cfl_bytes"]) < 0).all()
    assert (c["cfl_bytes"] > c["ccs_fuzzy_bytes"]).all()


def test_fig9_ordering():
    """DCS < CCS-fuzzy = CCS in accumulated time; all decrease with the
    interval; all exceed the model-only floor."""
    iv = np.array([0.5, 1.0, 5.0, 20.0])
    c = fig9_curves(iv)
    assert (c["dcs"] < c["ccs"]).all()
    assert (c["dcs"] < c["ccs-fuzzy"]).all()
    assert (np.diff(c["dcs"]) < 0).all()
    assert (c["dcs"] > c["model-only"]).all()


def test_fig9_latency_ratio():
    """With state messages dominating, DCS/CCS time ratio approaches the
    DSRC/cloud latency ratio 40/200."""
    p = IoVParams()
    dcs = accumulated_time_s("dcs", 0.1, p)
    ccs = accumulated_time_s("ccs", 0.1, p)
    assert dcs / ccs == pytest.approx(0.2, abs=0.02)
