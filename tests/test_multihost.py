"""Multi-process ``clients`` mesh (ISSUE 9: emulated multi-host fleet).

Spawns 2 coordinated CPU jax processes (gloo collectives) per test —
the same wiring ``--multihost 2`` uses — and checks:

- distributed init + a cross-process psum over the global clients mesh;
- the windowed sharded prefix under a 2-process mesh emits masks
  bit-identical to the same simulation in a single process;
- a tiny end-to-end ``fl_sim --multihost 2`` launch completes and
  writes output from process 0 only.

Every test gracefully skips when the runtime cannot form the
2-process group (no gloo CPU collectives in the jaxlib build, or the
coordination service cannot bind) — the capability probe runs once per
session and is itself a spawned pair of processes.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

_PROBE = r"""
import sys
from repro.launch.mesh import init_distributed
coord, procs, pid = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
init_distributed(coord, procs, pid, local_devices=2)
import jax
assert jax.process_count() == procs, jax.process_count()
assert len(jax.devices()) == 2 * procs, len(jax.devices())
print("PROBE_OK", pid)
"""


def _spawn_pair(child_src: str, extra_args=(), timeout=600):
    """Run ``child_src`` as 2 coordinated processes (argv: coord procs
    pid [extra...]); returns (rc, stdout_of_proc0, stderr_both)."""
    from repro.launch.multihost import free_port
    coord = f"127.0.0.1:{free_port()}"
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)           # children pick their own count
    procs = [subprocess.Popen(
        [sys.executable, "-c", child_src, coord, "2", str(pid),
         *map(str, extra_args)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env, cwd=REPO) for pid in range(2)]
    outs = []
    try:
        for p in procs:
            outs.append(p.communicate(timeout=timeout))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    rc = max(p.returncode for p in procs)
    return rc, outs[0][0], "\n".join(o[1] for o in outs)


@pytest.fixture(scope="session")
def multihost_available():
    rc, out, err = _spawn_pair(_PROBE, timeout=300)
    if rc != 0 or "PROBE_OK" not in out:
        pytest.skip(f"2-process jax runtime unavailable: {err[-800:]}")
    return True


@pytest.mark.slow
def test_distributed_psum_across_processes(multihost_available):
    child = r"""
import sys
from repro.launch.mesh import init_distributed, make_multihost_clients_mesh
coord, procs, pid = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
init_distributed(coord, procs, pid, local_devices=2)
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import shard_map
mesh = make_multihost_clients_mesh(4)
x = np.arange(8, dtype=np.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("clients")))
tot = jax.jit(shard_map(
    lambda v: jax.lax.psum(v.sum(), "clients"),
    mesh=mesh, in_specs=P("clients"), out_specs=P()))(xs)
assert float(jax.device_get(tot)) == float(x.sum()), tot
print("PSUM_OK", pid)
"""
    rc, out, err = _spawn_pair(child)
    assert rc == 0, f"psum child failed:\n{err[-3000:]}"
    assert "PSUM_OK" in out


@pytest.mark.slow
def test_windowed_prefix_parity_across_processes(multihost_available):
    """The tentpole's 2-process acceptance: the windowed sharded prefix
    on a mesh spanning 2 jax processes produces the same masks as the
    identical simulation run single-process (which is itself pinned to
    the dense election elsewhere)."""
    child = r"""
import sys
coord, procs, pid = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
multi = procs > 0
if multi:
    from repro.launch.mesh import init_distributed
    init_distributed(coord, procs, pid, local_devices=2)
else:
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.launch.mesh import make_clients_mesh, \
    make_multihost_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding

N = 10
cfg = FLSimConfig(
    scheme="dcs", n_rounds=2, local_epochs=1, samples_per_class=260,
    probe_samples=64, seed=0,
    partition=PartitionConfig(n_clients=N, big_clients=3,
                              big_quantity=120, small_quantity=40,
                              classes_per_client=9, seed=0),
    mobility=MobilityConfig(n_vehicles=N, seed=0))
mesh = make_multihost_clients_mesh(4) if multi else make_clients_mesh(4)
with mesh, logical_sharding(mesh, DEFAULT_RULES):
    sim = FLSimulation(cfg, run=RunConfig(elect="windowed"))
    masks = []
    for r in range(2):
        host = sim.resolve_elect_overflow(
            r, jax.device_get(sim.selection_state(r)))
        masks.append(np.asarray(host["mask"]).tolist())
print("MASKS" + json.dumps(masks))
"""
    rc, out, err = _spawn_pair(child)
    assert rc == 0, f"2-process prefix child failed:\n{err[-3000:]}"
    multi_masks = _extract_masks(out)

    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    single = subprocess.run(
        [sys.executable, "-c", child, "unused", "0", "0"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert single.returncode == 0, \
        f"single-process reference failed:\n{single.stderr[-3000:]}"
    assert multi_masks == _extract_masks(single.stdout), \
        "2-process windowed masks diverge from single-process"


def _extract_masks(out: str):
    for line in out.splitlines():
        if line.startswith("MASKS"):
            return json.loads(line[len("MASKS"):])
    raise AssertionError(f"no MASKS line in output: {out[-500:]!r}")


@pytest.mark.slow
def test_fl_sim_multihost_launch(multihost_available, tmp_path):
    """End-to-end ``fl_sim --multihost 2``: the parent re-spawns itself,
    the children form the mesh, and process 0 writes the output file."""
    out = tmp_path / "mh.json"
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.fl_sim", "--scheme", "dcs",
         "--rounds", "1", "--mesh", "clients=4", "--multihost", "2",
         "--elect", "windowed", "--jit-cache-dir", "none",
         "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)
    assert proc.returncode == 0, \
        f"fl_sim --multihost failed:\n{proc.stderr[-3000:]}\n" \
        f"{proc.stdout[-1000:]}"
    data = json.loads(out.read_text())
    assert "dcs" in data and len(data["dcs"]) == 1
    assert "2 processes" in proc.stdout
