"""shard_map expert-parallel MoE vs the dense dispatch path.

The EP path only activates under a production mesh, so this test spawns a
subprocess with 8 forced host devices and compares outputs on a (2,4)
(data, model) mesh against the dense reference, plus the EP invariants.
"""
import json
import os
import subprocess
import sys

import pytest

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_arch, scaled_down
from repro.models.moe import _apply_moe_dense, _apply_moe_ep, init_moe
from repro.sharding import DEFAULT_RULES, logical_sharding

cfg = dataclasses.replace(scaled_down(get_arch("qwen3-moe-30b-a3b")),
                          num_experts=4, experts_per_token=2,
                          capacity_factor=8.0)   # dropless => paths agree
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p = init_moe(key, cfg, cfg.d_model)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)

y_dense, aux_d = _apply_moe_dense(cfg, p, x)

with mesh, logical_sharding(mesh, DEFAULT_RULES):
    y_ep, aux_e = jax.jit(lambda pp, xx: _apply_moe_ep(cfg, pp, xx, mesh))(
        p, x)

err = float(jnp.abs(y_dense - y_ep).max())
scale = float(jnp.abs(y_dense).max())
load_d = np.asarray(aux_d["expert_load"])
load_e = np.asarray(aux_e["expert_load"])
out = {
    "err": err, "scale": scale,
    "lb_dense": float(aux_d["lb_loss"]), "lb_ep": float(aux_e["lb_loss"]),
    "load_err": float(np.abs(load_d - load_e).max()),
    "load_sum_ep": float(load_e.sum()),
}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_moe_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=500)
    assert proc.returncode == 0, proc.stderr[-2000:]
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    # capacity differs between global (dense) and per-shard (EP) dispatch;
    # with capacity_factor=8 both are dropless and must agree numerically
    assert data["err"] < 2e-2 * max(data["scale"], 1.0), data
    assert abs(data["lb_dense"] - data["lb_ep"]) < 0.05, data
    assert data["load_err"] < 0.02, data
    assert abs(data["load_sum_ep"] - 1.0) < 1e-3, data
