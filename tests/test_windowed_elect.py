"""Windowed neighbour-exchange DCS election (ISSUE 9 acceptance).

Parity is THE invariant: whenever the windowed election reports
``overflow == 0`` its mask must be bit-identical to the dense
``neighbor_elect_ref`` on the same floats — across ties, duplicate
positions, undersized windows, churned fleets and ``N % K != 0``
padding.  The property suite pins the single-device windowed path
(jnp + pallas-interpret) against both the dense reference and the
windowed oracle (which additionally certifies the no-under-flagging
contract); the subprocess test pins the shard_map'd ring-halo election
(forced 4- and 8-device meshes) and the driver's gather fallback on a
forced buffer overflow.

Satellite coverage rides along: the adaptive ``_pick_blocks`` lane
picker for the dense Pallas kernel, the ``shard_client_range`` per-host
loading helper, the windowed RunConfig knobs, and the persistent jit
compilation cache.
"""
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.elect import auto_capacity, auto_window, windowed_elect
from repro.core.selection import dcs_select, dcs_select_windowed
from repro.fl.partition import shard_client_range
from repro.fl.runconfig import AUTO_WINDOWED_MIN_CLIENTS, RunConfig
from repro.kernels.neighbor_elect import _pick_blocks
from repro.kernels.ref import neighbor_elect_ref, windowed_elect_ref
from repro.launch.cache import resolve_cache_dir

REPO = Path(__file__).resolve().parent.parent


# -- adaptive dense-kernel blocks (satellite) --------------------------------

def test_pick_blocks_small_fleet_stops_padding():
    """A 96-vehicle fleet must land on 128 lanes, not 1024."""
    bi, bj, np_ = _pick_blocks(96)
    assert np_ == 128 and bi <= 128 and bj <= 128
    assert np_ % bi == 0 and np_ % bj == 0


@pytest.mark.parametrize("n", [1, 30, 96, 128, 129, 256, 1000, 1024, 2048])
def test_pick_blocks_invariants(n):
    bi, bj, np_ = _pick_blocks(n)
    assert np_ >= n and np_ % 128 == 0
    assert np_ % bi == 0 and np_ % bj == 0     # whole grid steps
    assert np_ - n < 128                        # minimal 128-padding


def test_pick_blocks_large_keeps_tuned_tiles():
    bi, bj, np_ = _pick_blocks(2048)
    assert (bi, bj, np_) == (256, 1024, 2048)


@pytest.mark.parametrize("n", [5, 96, 130])
def test_dense_pallas_adaptive_blocks_match_ref(n):
    rng = np.random.default_rng(n)
    pos = jnp.asarray(rng.uniform(0, 1000, n).astype(np.float32))
    ev = jnp.asarray(rng.uniform(0, 100, n).astype(np.float32))
    from repro.kernels.neighbor_elect import neighbor_elect_pallas
    got = neighbor_elect_pallas(pos, ev, comm_range=200.0, top_m=2,
                                e_tau=30.0, interpret=True)
    want = neighbor_elect_ref(pos, ev, comm_range=200.0, top_m=2,
                              e_tau=30.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- single-device windowed parity (tentpole, property suite) ----------------

def _check_windowed(pos, ev, *, comm_range, top_m, e_tau, window, impl):
    pos = jnp.asarray(pos, jnp.float32)
    ev = jnp.asarray(ev, jnp.float32)
    mask, ovf = windowed_elect(pos, ev, comm_range=comm_range, top_m=top_m,
                               e_tau=e_tau, window=window, impl=impl)
    omask, oovf = windowed_elect_ref(pos, ev, comm_range=comm_range,
                                     top_m=top_m, e_tau=e_tau,
                                     window=window)
    dense = neighbor_elect_ref(pos, ev, comm_range=comm_range, top_m=top_m,
                               e_tau=e_tau)
    # the oracle's own contract (dense mask; overflow from rank distance)
    np.testing.assert_array_equal(np.asarray(omask), np.asarray(dense))
    # no under-flagging: the impl must flag whenever the oracle does
    assert int(ovf) >= int(oovf), \
        f"impl={impl} window={window}: under-flagged overflow"
    if int(ovf) == 0:
        np.testing.assert_array_equal(
            np.asarray(mask), np.asarray(dense),
            err_msg=f"impl={impl} window={window}: mask != dense with "
                    f"overflow=0")


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 40), st.integers(0, 10**6),
       st.integers(1, 44), st.sampled_from([50.0, 200.0, 1000.0]),
       st.sampled_from([0.0, 30.0, 101.0]), st.integers(1, 3),
       st.sampled_from(["jnp", "pallas"]))
def test_windowed_matches_dense_or_flags(n, seed, window, comm_range,
                                         e_tau, top_m, impl):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0, 1000.0, n).astype(np.float32)
    ev = rng.uniform(0, 100.0, n).astype(np.float32)
    if seed % 3 == 0:            # duplicate positions (sort-tie stress)
        pos = np.round(pos, -1)
    if seed % 4 == 0:            # eval ties (index tie-break stress)
        ev = np.round(ev, -1)
    _check_windowed(pos, ev, comm_range=comm_range, top_m=top_m,
                    e_tau=e_tau, window=window, impl=impl)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_windowed_all_tied_evals(impl):
    """Every eval identical: selection is decided purely by the index
    tie-break — the hardest bit-parity case."""
    n = 24
    rng = np.random.default_rng(7)
    pos = rng.uniform(0, 300.0, n).astype(np.float32)
    ev = np.full(n, 50.0, np.float32)
    for window in (1, 4, n + 1):
        _check_windowed(pos, ev, comm_range=200.0, top_m=2, e_tau=30.0,
                        window=window, impl=impl)


def test_windowed_empty_fleet_below_threshold():
    """Nobody clears e_tau: mask all-zero, never an overflow (there is
    no comparison the window could have missed that matters)."""
    pos = jnp.asarray(np.linspace(0, 100, 16), jnp.float32)
    ev = jnp.full((16,), 5.0, jnp.float32)
    mask, ovf = windowed_elect(pos, ev, comm_range=200.0, top_m=2,
                               e_tau=30.0, window=2)
    assert int(mask.sum()) == 0


def test_dcs_select_windowed_full_window_equals_dense():
    n = 30
    rng = np.random.default_rng(3)
    pos = jnp.asarray(rng.uniform(0, 1000, n).astype(np.float32))
    ev = jnp.asarray(rng.uniform(0, 100, n).astype(np.float32))
    mask, ovf = dcs_select_windowed(pos, ev, window=n)
    assert int(ovf) == 0
    np.testing.assert_array_equal(np.asarray(mask),
                                  np.asarray(dcs_select(pos, ev)))


# -- sizing helpers + config plumbing (satellites) ---------------------------

def test_auto_window_scales_with_density_not_fleet():
    # fixed density: the window is flat in N
    assert auto_window(10_000, 200.0, 10_000.0) \
        == auto_window(100_000, 200.0, 100_000.0)
    # denser road -> bigger window, clamped to the fleet
    assert auto_window(1000, 200.0, 500.0) == 1000
    # the 16 floor dominates tiny fleets (oversized windows are clipped
    # to the array downstream, so this only buys safety)
    assert auto_window(8, 200.0, 1e9) == 16


def test_auto_capacity_bounds():
    assert auto_capacity(64, 8) == 32        # 2*8 + 16
    assert auto_capacity(8, 8) == 8          # never beyond the shard


def test_shard_client_range_partitions_exactly():
    for n, k in [(30, 8), (10, 4), (16, 16), (7, 3), (5, 8)]:
        seen = []
        for d in range(k):
            seen.extend(shard_client_range(n, k, d))
        assert seen == list(range(n)), (n, k)
    assert list(shard_client_range(5, 8, 7)) == []    # empty tail shard
    with pytest.raises(ValueError):
        shard_client_range(10, 4, 4)


def test_runconfig_elect_auto_resolution():
    small = RunConfig().to_stage_config(
        _min_cfg(), n_clients=AUTO_WINDOWED_MIN_CLIENTS - 1)
    big = RunConfig().to_stage_config(
        _min_cfg(), n_clients=AUTO_WINDOWED_MIN_CLIENTS)
    assert small.elect == "gather" and big.elect == "windowed"
    forced = RunConfig(elect="windowed", elect_window=7).to_stage_config(
        _min_cfg(), n_clients=8)
    assert forced.elect == "windowed" and forced.elect_window == 7
    with pytest.raises(ValueError):
        RunConfig(elect="bogus").resolved()


def _min_cfg():
    from repro.fl.rounds import FLSimConfig
    return FLSimConfig(scheme="dcs")


def test_resolve_cache_dir_default_and_disable():
    assert resolve_cache_dir(None, "/tmp/x/out.json") == "/tmp/x/.jit-cache"
    assert resolve_cache_dir("none", "/tmp/x/out.json") is None
    assert resolve_cache_dir("", "/tmp/x/out.json") is None
    assert resolve_cache_dir("/d", "/tmp/x/out.json") == "/d"


def test_jit_cache_populates(tmp_path):
    """enable_jit_cache must actually persist CPU executables (the
    default thresholds would skip them) — run a tiny jit in a subprocess
    and check the directory gained entries."""
    cache = tmp_path / "jc"
    child = (
        "from repro.launch.cache import enable_jit_cache\n"
        f"enable_jit_cache({str(cache)!r})\n"
        "import jax, jax.numpy as jnp\n"
        "print(int(jax.jit(lambda x: (x * 3 + 1).sum())"
        "(jnp.arange(128.0))))\n")
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "JAX_PLATFORMS": "cpu"}
    proc = subprocess.run([sys.executable, "-c", child],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert cache.is_dir() and any(cache.iterdir()), \
        "persistent jit cache stayed empty"


def test_multihost_arg_plumbing():
    import argparse

    from repro.launch.multihost import (add_multihost_arguments,
                                        multihost_from_args, should_spawn)
    ap = argparse.ArgumentParser()
    add_multihost_arguments(ap)
    parent = ap.parse_args(["--multihost", "2"])
    assert should_spawn(parent) and multihost_from_args(parent) is None
    child = ap.parse_args(["--multihost", "2", "--_mh-coord",
                           "127.0.0.1:9999", "--_mh-procs", "2",
                           "--_mh-proc-id", "1"])
    assert not should_spawn(child)
    assert multihost_from_args(child) == ("127.0.0.1:9999", 2, 1)
    assert not should_spawn(ap.parse_args([]))


# -- sharded ring-halo parity + driver fallback (subprocess) -----------------

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import dataclasses
import json
import numpy as np
import jax
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig
from repro.launch.mesh import make_clients_mesh
from repro.sharding.api import DEFAULT_RULES, logical_sharding

def cfg(scheme, n, seed=0, **kw):
    return FLSimConfig(
        scheme=scheme, n_rounds=2, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=n, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=n, seed=seed), **kw)

def states(scheme, n, k, run, rounds=2, **kw):
    if k == 0:
        sim = FLSimulation(cfg(scheme, n, **kw), run=run)
        return [jax.device_get(sim.resolve_elect_overflow(
            r, jax.device_get(sim.selection_state(r))))
            for r in range(rounds)], sim
    mesh = make_clients_mesh(k)
    with mesh, logical_sharding(mesh, DEFAULT_RULES):
        sim = FLSimulation(cfg(scheme, n, **kw), run=run)
        return [jax.device_get(sim.resolve_elect_overflow(
            r, jax.device_get(sim.selection_state(r))))
            for r in range(rounds)], sim

out = {"ok": False}
gather = RunConfig(elect="gather")
windowed = RunConfig(elect="windowed")

# windowed == gather == unsharded, N % K != 0 padding, churn on/off,
# across forced 4- and 8-device meshes and both N=10 and N=30
n_windowed_sel = 0
for scheme in ("dcs", "ccs-fuzzy", "random"):
    for n, k, churn in [(10, 4, 0.0), (10, 8, 0.3), (30, 8, 0.0),
                        (30, 4, 0.3)]:
        rg = dataclasses.replace(gather, churn_rate=churn).resolved()
        rw = dataclasses.replace(windowed, churn_rate=churn).resolved()
        a, _ = states(scheme, n, 0, rg)
        b, _ = states(scheme, n, k, rg)
        c, simw = states(scheme, n, k, rw)
        for r, (sa, sb, sc) in enumerate(zip(a, b, c)):
            np.testing.assert_array_equal(
                np.asarray(sa["mask"]), np.asarray(sb["mask"]),
                err_msg=f"{scheme} n={n} k={k} r={r}: gather != unsharded")
            np.testing.assert_array_equal(
                np.asarray(sa["mask"]), np.asarray(sc["mask"]),
                err_msg=f"{scheme} n={n} k={k} r={r}: windowed != dense")
            assert int(sa["n_selected"]) == int(sc["n_selected"])
            n_windowed_sel += int(np.asarray(sc["mask"]).sum())
out["windowed_selected"] = n_windowed_sel
assert n_windowed_sel > 0, "degenerate: windowed never selected anyone"

# eval ties at shard boundaries: a constant-eval fleet forces every
# decision through the global-index tie-break across the halo exchange
mesh = make_clients_mesh(8)
with mesh, logical_sharding(mesh, DEFAULT_RULES):
    import jax.numpy as jnp
    from repro.core.elect import (auto_capacity, auto_window,
                                  ring_halo_elect)
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels.ref import neighbor_elect_ref
    n, k, road = 64, 8, 400.0
    rng = np.random.default_rng(11)
    for tie in (False, True):
        pos = rng.uniform(0, road, n).astype(np.float32)
        ev = (np.full(n, 55.0, np.float32) if tie
              else rng.uniform(0, 100, n).astype(np.float32))
        def body(p, e, g, v):
            m_, o_ = ring_halo_elect(
                p, e, g, v, axis="clients", n=n, n_shards=k,
                shard_n=n // k, comm_range=120.0, top_m=2, e_tau=30.0,
                road_length=road, window=auto_window(n, 120.0, road),
                capacity=auto_capacity(n // k, k))
            return m_, jax.lax.pmax(o_, "clients")
        fn = shard_map(body, mesh=mesh, in_specs=(P("clients"),) * 4,
                       out_specs=(P("clients"), P()))
        mask, ovf = fn(jnp.asarray(pos), jnp.asarray(ev),
                       jnp.arange(n, dtype=jnp.int32),
                       jnp.ones(n, bool))
        assert int(ovf) == 0, f"tie={tie}: unexpected overflow"
        dense = neighbor_elect_ref(jnp.asarray(pos), jnp.asarray(ev),
                                   comm_range=120.0, top_m=2, e_tau=30.0)
        np.testing.assert_array_equal(
            np.asarray(mask), np.asarray(dense),
            err_msg=f"boundary ties tie={tie}: ring halo != dense")

# forced overflow (capacity=1): the prefix must FLAG, and the driver
# fallback must land on the bit-exact dense masks
mesh = make_clients_mesh(8)
with mesh, logical_sharding(mesh, DEFAULT_RULES):
    sim = FLSimulation(cfg("dcs", 30), run=windowed)
    sim.stage_cfg = dataclasses.replace(sim.stage_cfg, elect_capacity=1)
    raw = jax.device_get(sim.selection_state(0))
    assert int(np.max(raw["elect_overflow"])) == 1, \
        "capacity=1 did not raise the overflow flag"
    fixed = sim.resolve_elect_overflow(0, raw)
    ref = FLSimulation(cfg("dcs", 30), run=gather)
    want = jax.device_get(ref.selection_state(0))
    np.testing.assert_array_equal(np.asarray(fixed["mask"]),
                                  np.asarray(want["mask"]))
out["overflow_fallback"] = True

out["ok"] = True
print(json.dumps(out))
"""


@pytest.mark.slow
def test_windowed_sharded_parity_and_fallback():
    """Tentpole acceptance: ring-halo windowed masks bit-identical to
    the gather election and the unsharded pipeline on forced 4/8-device
    meshes (churn, padding, boundary ties), and the capacity-overflow
    driver fallback reproduces the dense masks exactly."""
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          cwd=REPO, timeout=2400)
    assert proc.returncode == 0, \
        f"windowed sharded parity child failed:\n{proc.stderr[-4000:]}"
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["ok"] and data["overflow_fallback"]
    assert data["windowed_selected"] > 0
