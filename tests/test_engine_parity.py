"""Parity between the batched vmapped round engine and the reference
per-client loop engine (ISSUE 1 acceptance): identical selection masks
and matching accuracy trajectories for all three schemes, plus
straggler masking via zeroed FedAvg weights.

ISSUE 2 extends the same harness to the capacity-grouped engine: the
standard profile below already yields two capacity groups (120- and
40-sample quantities), a dedicated test drives a Table-3-shaped skew,
and empty rounds (nobody clears selection + deadline) must be a no-op
broadcast in both engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.aggregation import fedavg, fedavg_masked
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation
from repro.fl.runconfig import RunConfig

N_CLIENTS = 10
N_ROUNDS = 3


def _cfg(scheme: str, **kw) -> FLSimConfig:
    kw.setdefault("partition",
                  PartitionConfig(n_clients=N_CLIENTS, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9))
    kw.setdefault("mobility", MobilityConfig(n_vehicles=N_CLIENTS, seed=0))
    return FLSimConfig(
        scheme=scheme, n_rounds=N_ROUNDS, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=0, **kw)


def _sim(scheme: str, engine: str, **kw) -> FLSimulation:
    return FLSimulation(_cfg(scheme, **kw), run=RunConfig(engine=engine))


def _run(scheme: str, engine: str, **kw):
    sim = _sim(scheme, engine, **kw)
    rows, masks = [], []
    for r in range(N_ROUNDS):
        rows.append(sim.run_round(r))
        masks.append(sim.last_mask.copy())
    return rows, masks


@pytest.mark.parametrize("scheme", ["dcs", "ccs-fuzzy", "random"])
def test_engine_parity(scheme):
    rows_l, masks_l = _run(scheme, "loop")
    rows_b, masks_b = _run(scheme, "batched")
    for r in range(N_ROUNDS):
        np.testing.assert_array_equal(
            masks_l[r], masks_b[r],
            err_msg=f"{scheme} round {r}: selection masks diverge")
        assert rows_l[r]["n_selected"] == rows_b[r]["n_selected"]
        assert rows_l[r]["n_aggregated"] == rows_b[r]["n_aggregated"]
        assert rows_l[r]["n_straggler"] == rows_b[r]["n_straggler"]
        assert abs(rows_l[r]["accuracy"] - rows_b[r]["accuracy"]) <= 1e-5, \
            f"{scheme} round {r}: accuracy diverges"


def test_engine_rejects_unknown():
    with pytest.raises(ValueError):
        FLSimulation(_cfg("dcs"), run=RunConfig(engine="other"))


def test_dataset_loss_batch_matches_per_client():
    """The stacked-cohort probe API agrees with per-client dataset_loss,
    including when C*cap is not a multiple of the chunk size."""
    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.fl.client import dataset_loss, dataset_loss_batch
    from repro.models.cnn import init_cnn

    params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    im = jax.random.normal(jax.random.PRNGKey(1), (5, 60, 28, 28, 1))
    lb = jnp.zeros((5, 60), jnp.int32).at[:, :40].set(2)
    nv = jnp.arange(10, 60, 10, dtype=jnp.int32)        # ragged validity
    got = np.asarray(dataset_loss_batch(params, im, lb, nv, batch=128))
    want = np.array([float(dataset_loss(params, im[i], lb[i], nv[i],
                                        batch=128)) for i in range(5)])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# straggler masking
# --------------------------------------------------------------------------

def test_fedavg_masked_zero_weight_rows_drop_out():
    """A zero FedAvg weight is exactly equivalent to skipping the model."""
    rows = jnp.arange(12.0).reshape(3, 4)
    stacked = {"w": rows}
    out = fedavg_masked(stacked, jnp.array([2.0, 0.0, 1.0]))
    ref = fedavg([{"w": rows[0]}, {"w": rows[2]}], [2.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-6)


def test_grouped_parity_table3_skew():
    """Grouped-engine parity on a Table-3-shaped quantity skew (200 vs 45
    samples -> two capacity groups): masks identical to the loop engine,
    accuracy within 1e-5."""
    kw = dict(partition=PartitionConfig(n_clients=N_CLIENTS, big_clients=3,
                                        big_quantity=200, small_quantity=45,
                                        classes_per_client=9))
    rows_l, masks_l = _run("dcs", "loop", **kw)
    rows_b, masks_b = _run("dcs", "batched", **kw)
    sim = _sim("dcs", "batched", **kw)
    assert [g.cap for g in sim.groups] == [200, 60]
    for r in range(N_ROUNDS):
        np.testing.assert_array_equal(masks_l[r], masks_b[r])
        assert rows_l[r]["n_aggregated"] == rows_b[r]["n_aggregated"]
        assert abs(rows_l[r]["accuracy"] - rows_b[r]["accuracy"]) <= 1e-5


def test_uniform_capacity_single_group():
    """uniform_capacity=True reproduces the PR-1 single max-cap stack."""
    sim = _sim("dcs", "batched", uniform_capacity=True)
    assert len(sim.groups) == 1
    assert sim.groups[0].cap == sim.cap
    assert sim.groups[0].size == N_CLIENTS


def test_partial_group_cohort_parity():
    """A cohort confined to one capacity group trains identically in both
    engines (the batched engine must skip the other group's empty cohort
    rather than pad from it)."""
    sim_b = _sim("dcs", "batched")
    sim_l = _sim("dcs", "loop")
    survivors = np.zeros(N_CLIENTS, bool)
    survivors[[4, 7]] = True                 # small-capacity clients only
    sim_b._train_batched(survivors, sim_b._round_keys(0))
    sim_l._train_loop(survivors, sim_l._round_keys(0))
    for a, b in zip(jax.tree.leaves(sim_b.params),
                    jax.tree.leaves(sim_l.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=2e-6)


# --------------------------------------------------------------------------
# empty rounds
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["loop", "batched"])
def test_empty_round_is_noop_broadcast(engine):
    """When every evaluation is below E_tau nobody is selected: the round
    must leave the global model bit-identical in both engines."""
    sim = _sim("dcs", engine, e_tau=1e9)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(sim.params)]
    row = sim.run_round(0)
    assert row["n_selected"] == 0
    assert row["n_aggregated"] == 0
    assert row["mean_eval_selected"] == 0.0
    for b, a in zip(before, jax.tree.leaves(sim.params)):
        np.testing.assert_array_equal(b, np.asarray(a))


def test_all_stragglers_leave_global_model_untouched():
    """With an unmeetable deadline every selected client straggles: the
    batched engine must aggregate nothing and keep the exact params."""
    sim = _sim("ccs-fuzzy", "batched", deadline_s=1e-9)
    before = [np.asarray(x).copy() for x in jax.tree.leaves(sim.params)]
    row = sim.run_round(0)
    assert row["n_selected"] > 0
    assert row["n_aggregated"] == 0
    assert row["n_straggler"] == row["n_selected"]
    after = jax.tree.leaves(sim.params)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, np.asarray(a))
