"""Staged-pipeline parity (ISSUE 3 acceptance): the jitted selection
prefix must produce masks bit-identical to the host-driven stage-by-stage
composition (the pre-refactor engine's data flow), and a round completed
through the pure stages must match ``FLSimulation.run_round`` exactly in
masks and within 1e-5 in accuracy.  Also: the seed-vmapped prefix agrees
with per-seed dispatches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import pipeline
from repro.fl.client import evaluate_accuracy
from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation

N_CLIENTS = 10
N_ROUNDS = 2


def _cfg(scheme: str, seed: int = 0, **kw) -> FLSimConfig:
    return FLSimConfig(
        scheme=scheme, n_rounds=N_ROUNDS, local_epochs=1,
        samples_per_class=260, probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=N_CLIENTS, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9, seed=seed),
        mobility=MobilityConfig(n_vehicles=N_CLIENTS, seed=seed), **kw)


def _eager_prefix(sim: FLSimulation, rnd: int):
    """The pre-refactor data flow: each stage called individually, host
    round-trips between stages, no outer jit."""
    st, cfg = sim.statics, sim.stage_cfg
    rnd = jnp.int32(rnd)
    t_s = rnd.astype(jnp.float32) * cfg.timing.deadline_s
    k_sel = jax.random.fold_in(sim.key, rnd)
    k_pred, k_upload = jax.random.split(
        jax.random.fold_in(sim.net_key, rnd))
    pos, feats = pipeline.features(st, cfg, sim.params, t_s, k_pred)
    evals = pipeline.evaluate(st, jnp.asarray(np.asarray(feats)))
    mask = pipeline.select(cfg, jnp.asarray(np.asarray(pos)), evals, k_sel)
    survivors, n_straggler = pipeline.deadline_filter(
        st, cfg, pos, jnp.asarray(np.asarray(mask)), k_upload)
    return {"pos": pos, "evals": evals, "mask": mask,
            "survivors": survivors, "n_straggler": n_straggler}


@pytest.mark.parametrize("scheme", ["dcs", "ccs-fuzzy", "random"])
def test_jitted_prefix_bitwise_matches_eager_stages(scheme):
    """ISSUE 3 acceptance: the one-jit staged prefix emits masks
    bit-identical to the stage-by-stage host-driven composition."""
    sim = FLSimulation(_cfg(scheme))
    for r in range(N_ROUNDS):
        jitted = jax.device_get(sim.selection_state(r))
        eager = jax.device_get(_eager_prefix(sim, r))
        np.testing.assert_array_equal(
            np.asarray(jitted["mask"]), np.asarray(eager["mask"]),
            err_msg=f"{scheme} round {r}: jitted vs eager masks diverge")
        np.testing.assert_array_equal(np.asarray(jitted["survivors"]),
                                      np.asarray(eager["survivors"]))
        assert int(jitted["n_straggler"]) == int(eager["n_straggler"])
        np.testing.assert_allclose(np.asarray(jitted["evals"]),
                                   np.asarray(eager["evals"]),
                                   rtol=1e-4, atol=1e-3)


def test_staged_round_matches_run_round():
    """Completing rounds through the pure stages (eager prefix +
    train_groups + aggregate) reproduces FLSimulation.run_round:
    identical masks, accuracy within 1e-5."""
    sim = FLSimulation(_cfg("dcs"))           # the reference driver
    staged = FLSimulation(_cfg("dcs"))        # driven through the stages
    cfg = staged.cfg
    for r in range(N_ROUNDS):
        row = sim.run_round(r)
        state = jax.device_get(_eager_prefix(staged, r))
        survivors = np.asarray(state["survivors"])
        np.testing.assert_array_equal(sim.last_mask,
                                      np.asarray(state["mask"]),
                                      err_msg=f"round {r}: masks diverge")
        trained = pipeline.train_groups(
            staged.params, staged.groups, staged._group_steps, survivors,
            staged._round_keys(r), epochs=cfg.local_epochs,
            batch_size=cfg.batch_size, lr=cfg.lr, prox_mu=cfg.prox_mu)
        staged.params = pipeline.aggregate(staged.params, trained)
        acc = evaluate_accuracy(staged.params, staged.test_images,
                                staged.test_labels, batch=256)
        assert abs(row["accuracy"] - acc) <= 1e-5, f"round {r}"


def test_vmapped_prefix_matches_per_seed():
    """selection_prefix_seeds (one dispatch, S seeds) agrees with S
    independent selection_prefix dispatches: same masks/survivors, evals
    within float tolerance."""
    sims = [FLSimulation(_cfg("dcs", seed=s)) for s in (0, 1)]
    cfg0 = sims[0].stage_cfg
    assert all(s.stage_cfg == cfg0 for s in sims)
    stacked = pipeline.stack_statics([s.statics for s in sims])
    for r in range(N_ROUNDS):
        params = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[s.params for s in sims])
        outs = jax.device_get(pipeline.selection_prefix_seeds(
            stacked, params, jnp.int32(r),
            jnp.stack([s.key for s in sims]),
            jnp.stack([s.net_key for s in sims]), cfg=cfg0))
        for i, sim in enumerate(sims):
            single = jax.device_get(sim.selection_state(r))
            np.testing.assert_array_equal(
                np.asarray(outs["mask"])[i], np.asarray(single["mask"]),
                err_msg=f"seed {i} round {r}: vmapped mask diverges")
            np.testing.assert_array_equal(
                np.asarray(outs["survivors"])[i],
                np.asarray(single["survivors"]))
            np.testing.assert_allclose(
                np.asarray(outs["evals"])[i], np.asarray(single["evals"]),
                rtol=1e-3, atol=0.2)
            # training must consume either state identically
            sim.finish_round(r, jax.tree.map(lambda x, i=i: x[i], outs))


def test_prefix_deterministic_in_round():
    """The prefix is pure in (statics, params, rnd, keys): re-querying a
    round returns bit-identical state (needed by staleness-style
    experiments and the sweep's re-dispatch)."""
    sim = FLSimulation(_cfg("random"))
    a = jax.device_get(sim.selection_state(0))
    b = jax.device_get(sim.selection_state(0))
    for k in ("mask", "survivors", "evals", "pos"):
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_post_construction_calibration_takes_effect():
    """§5.3 calibration after FLSimulation construction must influence
    the next round's evaluations (selection_state re-reads the
    evaluator's membership parameters), matching the host-driven
    engine's live-read semantics."""
    sim = FLSimulation(_cfg("dcs"))
    before = np.asarray(jax.device_get(sim.selection_state(0))["evals"])
    history = np.random.default_rng(0).beta(2, 5, size=(500, 4))
    sim.evaluator.calibrate(history)
    after = np.asarray(jax.device_get(sim.selection_state(0))["evals"])
    assert not np.allclose(before, after)


def test_train_groups_empty_round_is_none():
    """Stage contract: an empty survivor mask yields None and aggregate
    broadcasts the unchanged global model."""
    sim = FLSimulation(_cfg("dcs"))
    trained = pipeline.train_groups(
        sim.params, sim.groups, sim._group_steps,
        np.zeros(N_CLIENTS, bool), sim._round_keys(0),
        epochs=1, batch_size=20, lr=0.05, prox_mu=0.0)
    assert trained is None
    out = pipeline.aggregate(sim.params, None)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(sim.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
