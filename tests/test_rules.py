"""Rule-base tests: the paper's published Table 2 rows + structural
properties of the reconstructed 81-rule table."""
import itertools

import numpy as np
import pytest

from repro.core.rules import (PAPER_ANCHORS, build_rule_table, consequent,
                              verify_anchors)


def test_table_size_and_range():
    table, levels = build_rule_table()
    assert table.shape == (81, 4)
    assert levels.shape == (81,)
    assert levels.min() >= 0 and levels.max() <= 8
    # every antecedent combination appears exactly once
    assert len({tuple(r) for r in table}) == 81


def test_paper_anchor_rows():
    """All nine published rows of Table 2 match (antecedent + level)."""
    assert verify_anchors()
    table, levels = build_rule_table()
    expected_antecedents = {
        1: (2, 2, 2, 2), 2: (1, 2, 2, 2), 3: (0, 2, 2, 2),
        52: (2, 0, 0, 1), 53: (1, 0, 0, 1), 54: (0, 0, 0, 1),
        79: (2, 0, 0, 0), 80: (1, 0, 0, 0), 81: (0, 0, 0, 0),
    }
    for rule_no, ante in expected_antecedents.items():
        assert tuple(table[rule_no - 1]) == ante, rule_no
        assert levels[rule_no - 1] == PAPER_ANCHORS[rule_no]


def test_monotonicity():
    """Raising any input level never lowers the consequent."""
    for combo in itertools.product(range(3), repeat=4):
        base = consequent(*combo)
        for j in range(4):
            if combo[j] < 2:
                up = list(combo)
                up[j] += 1
                assert consequent(*up) >= base, (combo, j)


def test_best_and_worst():
    assert consequent(2, 2, 2, 2) == 8
    assert consequent(0, 0, 0, 0) == 0
