"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step and one prefill+decode step on
CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch, scaled_down
from repro.configs.base import ShapeConfig
from repro.models import registry as R
from repro.train.optim import OptConfig, adamw_init
from repro.train.step import make_train_step

SMOKE_TRAIN = ShapeConfig("smoke_train", 64, 2, "train", grad_accum=2)
SMOKE_PREFILL = ShapeConfig("smoke_prefill", 64, 2, "prefill")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    cfg = scaled_down(get_arch(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    params = R.init_params(key, cfg)
    batch = R.make_concrete_batch(cfg, SMOKE_TRAIN, key, "train")
    step = make_train_step(cfg, SMOKE_TRAIN, OptConfig(total_steps=10))
    opt_state = adamw_init(params)
    params2, opt2, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["ce"]) > 0
    # params actually changed
    leaves1 = jax.tree.leaves(params)
    leaves2 = jax.tree.leaves(params2)
    changed = any(
        not jnp.allclose(a, b) for a, b in zip(leaves1, leaves2))
    assert changed
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, key):
    cfg = scaled_down(get_arch(arch))
    params = R.init_params(key, cfg)
    batch = R.make_concrete_batch(cfg, SMOKE_PREFILL, key, "prefill")
    logits, cache = R.prefill_fn(cfg)(params, batch, context=128)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    dec = R.decode_fn(cfg, 128)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-3b", "jamba-v0.1-52b"])
def test_prefill_decode_consistency(arch, key):
    """Greedy decode after prefill(prompt) matches prefill(prompt+token):
    the cache path and the full path agree.  MoE capacity is raised so no
    token is capacity-dropped (dropping makes the full path diverge from
    the per-token decode path by design)."""
    import dataclasses
    cfg = scaled_down(get_arch(arch))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = R.init_params(key, cfg)
    shape = ShapeConfig("c", 32, 1, "prefill")
    batch = R.make_concrete_batch(cfg, shape, key, "prefill")
    logits1, cache = R.prefill_fn(cfg)(params, batch, context=64)
    tok = jnp.argmax(logits1, -1).astype(jnp.int32)
    logits2, _ = R.decode_fn(cfg, 64)(params, cache, tok)

    batch_ext = dict(batch)
    batch_ext["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    logits_full, _ = R.prefill_fn(cfg)(params, batch_ext)
    # last-position logits should match the decode-step logits (bf16
    # accumulation-order noise scales with logit magnitude -> relative)
    a = jnp.asarray(logits2[:, -1], jnp.float32)
    b = jnp.asarray(logits_full[:, -1], jnp.float32)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.std(b) + 1e-6))
    assert rel < 0.1, rel
    assert jnp.array_equal(jnp.argmax(a, -1), jnp.argmax(b, -1))


def test_full_config_param_counts():
    """Analytic parameter counts are in the right ballpark for the
    published model sizes (sanity for roofline MODEL_FLOPS)."""
    expect = {
        "granite-8b": (6e9, 10e9),
        "yi-6b": (5e9, 7e9),
        "gemma-2b": (2e9, 3.5e9),
        "minicpm-2b": (2e9, 3.5e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "jamba-v0.1-52b": (46e9, 58e9),
        "whisper-medium": (0.25e9, 0.6e9),
        "paligemma-3b": (2.2e9, 3.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).num_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_below_total():
    for arch in ["phi3.5-moe-42b-a6.6b", "qwen3-moe-30b-a3b",
                 "jamba-v0.1-52b"]:
        cfg = get_arch(arch)
        assert cfg.num_active_params() < 0.5 * cfg.num_params()


def test_sliding_window_prefill_ring(key):
    """Prompt longer than the sliding window: the rolled ring cache +
    decode step must match the full windowed forward."""
    import dataclasses
    import jax.numpy as jnp
    from repro.models import transformer as tfm
    cfg = dataclasses.replace(scaled_down(get_arch("gemma-2b")),
                              sliding_window=64)
    params = R.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 100), 0, cfg.vocab_size)
    logits1, cache = tfm.prefill(cfg, params, {"tokens": toks}, context=128,
                                 window=64)
    assert cache["layers"]["k"].shape[2] == 64          # ring-sized
    tok = jnp.argmax(logits1, -1).astype(jnp.int32)
    logits2, _ = tfm.decode_step(cfg, params, cache, tok, window=64)

    full, _ = tfm.prefill(cfg, params,
                          {"tokens": jnp.concatenate([toks, tok], 1)},
                          window=64)
    a = jnp.asarray(logits2[:, -1], jnp.float32)
    b = jnp.asarray(full[:, -1], jnp.float32)
    rel = float(jnp.max(jnp.abs(a - b)) / (jnp.std(b) + 1e-6))
    assert rel < 0.1, rel
    assert jnp.array_equal(jnp.argmax(a, -1), jnp.argmax(b, -1))
