"""Layer-level unit tests: flash attention vs naive softmax, chunked CE vs
full logits CE, RoPE properties, decode-cache ring semantics, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch, scaled_down
from repro.models.attention import (decode_attention, flash_attention,
                                    make_kv_cache)
from repro.models.layers import chunked_cross_entropy
from repro.models.moe import apply_moe, moe_capacity, _positions_in_expert


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def _naive_attention(q, k, v, q_pos, kv_pos, causal=True, window=0,
                     prefix_len=0):
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok = kv_pos[None, :] <= q_pos[:, None]
        if window:
            ok &= (q_pos[:, None] - kv_pos[None, :]) < window
        if prefix_len:
            ok |= kv_pos[None, :] < prefix_len
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh)


@pytest.mark.parametrize("sq,skv,hq,hkv,window,prefix",
                         [(128, 128, 4, 2, 0, 0),
                          (256, 256, 4, 1, 64, 0),
                          (128, 128, 2, 2, 0, 32),
                          (96, 96, 4, 4, 0, 0)])     # irregular: single chunk
def test_flash_vs_naive(sq, skv, hq, hkv, window, prefix):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, sq, hq, 32))
    k = jax.random.normal(ks[1], (2, skv, hkv, 32))
    v = jax.random.normal(ks[2], (2, skv, hkv, 32))
    pos = jnp.arange(sq)
    out1 = flash_attention(q, k, v, pos, pos, window=window,
                           prefix_len=prefix, q_chunk=64, kv_chunk=64)
    out2 = _naive_attention(q, k, v, pos, pos, window=window,
                            prefix_len=prefix)
    np.testing.assert_allclose(np.asarray(out1, np.float32),
                               np.asarray(out2, np.float32),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    out1 = flash_attention(q, k, v, jnp.arange(64), jnp.arange(128),
                           causal=False, q_chunk=32, kv_chunk=32)
    out2 = _naive_attention(q, k, v, jnp.arange(64), jnp.arange(128),
                            causal=False)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# decode cache
# --------------------------------------------------------------------------

def test_decode_matches_full_attention_incremental():
    """Feeding tokens one-by-one through the ring cache equals full
    attention over the prefix at every step."""
    key = jax.random.PRNGKey(2)
    b, t, h, dh = 1, 12, 2, 16
    ks = jax.random.split(key, 3)
    qs = jax.random.normal(ks[0], (b, t, h, dh))
    kk = jax.random.normal(ks[1], (b, t, h, dh))
    vv = jax.random.normal(ks[2], (b, t, h, dh))
    cache = make_kv_cache(b, t, h, dh, dtype=jnp.float32)
    pos = jnp.arange(t)
    for i in range(t):
        out_dec, cache = decode_attention(
            qs[:, i:i+1], cache, kk[:, i:i+1], vv[:, i:i+1])
        out_full = _naive_attention(qs[:, :i+1], kk[:, :i+1], vv[:, :i+1],
                                    pos[:i+1], pos[:i+1])
        np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                                   np.asarray(out_full[:, -1]),
                                   atol=1e-4, rtol=1e-4)


def test_decode_ring_window():
    """With a ring cache of W slots, attention covers exactly the last W
    positions: outputs match full attention restricted to that window."""
    key = jax.random.PRNGKey(3)
    b, t, w, h, dh = 1, 20, 8, 1, 16
    ks = jax.random.split(key, 3)
    qs = jax.random.normal(ks[0], (b, t, h, dh))
    kk = jax.random.normal(ks[1], (b, t, h, dh))
    vv = jax.random.normal(ks[2], (b, t, h, dh))
    cache = make_kv_cache(b, w, h, dh, dtype=jnp.float32)
    pos = jnp.arange(t)
    for i in range(t):
        out_dec, cache = decode_attention(
            qs[:, i:i+1], cache, kk[:, i:i+1], vv[:, i:i+1], window=w)
        lo = max(0, i - w + 1)
        out_full = _naive_attention(qs[:, i:i+1], kk[:, lo:i+1],
                                    vv[:, lo:i+1], pos[i:i+1], pos[lo:i+1])
        np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                                   np.asarray(out_full[:, -1]),
                                   atol=1e-4, rtol=1e-4, err_msg=f"i={i}")


# --------------------------------------------------------------------------
# chunked CE
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(2, 9), st.sampled_from([48, 60, 64, 96, 3840]))
def test_chunked_ce_matches_full(seed, s):
    key = jax.random.PRNGKey(seed)
    b, d, v = 2, 16, 50
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (b, s, d))
    emb = jax.random.normal(ks[1], (v, d)) * 0.5
    labels = jax.random.randint(ks[2], (b, s), 0, v)
    mask = (jax.random.uniform(ks[2], (b, s)) > 0.2).astype(jnp.float32)
    tot, cnt = chunked_cross_entropy(x, emb, labels, mask, chunk=32)
    logits = (x @ emb.T).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    full = ((logz - gold) * mask).sum()
    np.testing.assert_allclose(float(tot), float(full), rtol=1e-5)
    assert float(cnt) == float(mask.sum())


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------

def test_positions_in_expert():
    flat = jnp.array([1, 0, 1, 1, 0, 2], jnp.int32)
    pos = np.asarray(_positions_in_expert(flat, 3))
    assert pos.tolist() == [0, 0, 1, 2, 1, 0]


def test_moe_forward_and_load():
    cfg = scaled_down(get_arch("qwen3-moe-30b-a3b"))
    from repro.models.moe import init_moe
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, cfg.d_model)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    y, aux = apply_moe(cfg, p, x)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
    np.testing.assert_allclose(float(aux["expert_load"].sum()), 1.0,
                               rtol=1e-3)
    assert float(aux["lb_loss"]) > 0.0


def test_moe_capacity_rounding():
    cfg = get_arch("phi3.5-moe-42b-a6.6b")
    c = moe_capacity(cfg, 1024)
    assert c % 8 == 0
    assert c >= 1024 * cfg.experts_per_token / cfg.num_experts


def test_moe_dropped_tokens_pass_through():
    """With capacity factor << 1 most tokens are dropped: output is
    near-zero for them (residual passes through in the layer)."""
    import dataclasses
    cfg = dataclasses.replace(scaled_down(get_arch("qwen3-moe-30b-a3b")),
                              capacity_factor=0.01)
    from repro.models.moe import init_moe
    key = jax.random.PRNGKey(1)
    p = init_moe(key, cfg, cfg.d_model)
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    y, _ = apply_moe(cfg, p, x)
    # many rows must be exactly zero (dropped)
    zero_rows = (jnp.abs(y[0]).max(-1) == 0).sum()
    assert int(zero_rows) > 16
