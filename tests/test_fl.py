"""FL substrate tests: partition invariants (hypothesis), aggregation
correctness, selection schemes, network predictor ordering, timing model,
and a short end-to-end FL round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.selection import (ccs_fuzzy_select, ccs_random_select,
                                  dcs_select)
from repro.data.synthetic import make_dataset, train_test_split
from repro.fl.aggregation import fedavg, global_loss
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.network import CellularNetwork, NetworkConfig
from repro.fl.partition import (PartitionConfig, group_capacity, partition,
                                pad_clients, stack_clients, steps_per_epoch)
from repro.fl.timing import TimingConfig, completes_before_deadline, \
    training_time_s


# --------------------------------------------------------------------------
# partition
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 9), st.sampled_from([2, 6, 9]))
def test_partition_invariants(seed, classes_per_client):
    images, labels = make_dataset(900, seed=seed)
    cfg = PartitionConfig(n_clients=10, classes_per_client=classes_per_client,
                          big_clients=4, big_quantity=400, small_quantity=45,
                          seed=seed)
    parts = partition(images, labels, cfg)
    # no duplication: total assigned <= dataset, and indices unique per size
    total = sum(len(p[1]) for p in parts)
    assert total <= len(labels)
    for im, lb in parts:
        assert len(np.unique(lb)) <= classes_per_client
    # unbalanced quantities honored (integer division slack allowed)
    for i, (im, lb) in enumerate(parts):
        want = cfg.big_quantity if i < cfg.big_clients else cfg.small_quantity
        assert abs(len(lb) - want) <= classes_per_client


def test_partition_no_duplicates_across_clients():
    images, labels = make_dataset(900, seed=0)
    # tag every sample with its index through a hash of pixel values
    cfg = PartitionConfig(n_clients=6, classes_per_client=9, big_clients=2,
                          big_quantity=360, small_quantity=45)
    parts = partition(images, labels, cfg)
    sigs = []
    for im, _ in parts:
        sigs.extend(im.reshape(len(im), -1).sum(1).round(4).tolist())
    # sums collide rarely; allow a tiny number of accidental equalities
    assert len(sigs) - len(set(sigs)) < len(sigs) * 0.01


def test_pad_clients_shapes():
    images, labels = make_dataset(300, seed=1)
    cfg = PartitionConfig(n_clients=4, classes_per_client=2, big_clients=1,
                          big_quantity=100, small_quantity=40)
    parts = partition(images, labels, cfg)
    im, lb, nv = pad_clients(parts, cap=120)
    assert im.shape == (4, 120, 28, 28, 1)
    assert (nv <= 120).all() and nv[0] >= 99


# --------------------------------------------------------------------------
# capacity groups
# --------------------------------------------------------------------------

def test_stack_clients_capacity_groups():
    """Quantity skew buckets into per-capacity groups (largest first) that
    cover every client exactly once and preserve the per-client data."""
    images, labels = make_dataset(300, seed=2)
    cfg = PartitionConfig(n_clients=6, classes_per_client=9, big_clients=2,
                          big_quantity=180, small_quantity=45)
    parts = partition(images, labels, cfg)
    groups = stack_clients(parts, batch_size=20)
    assert [g.cap for g in groups] == [180, 60]
    assert [g.size for g in groups] == [2, 4]
    seen = np.concatenate([g.client_ids for g in groups])
    assert sorted(seen.tolist()) == list(range(6))
    for g in groups:
        assert g.images.shape == (g.size, g.cap, 28, 28, 1)
        assert g.cap % 20 == 0
        for li, ci in enumerate(g.client_ids):
            n = int(g.n_valid[li])
            assert n == len(parts[ci][1])
            np.testing.assert_array_equal(g.images[li, :n], parts[ci][0])
            np.testing.assert_array_equal(g.labels[li, :n], parts[ci][1])
            assert (g.labels[li, n:] == 0).all()


def test_stack_clients_uniform_single_group():
    images, labels = make_dataset(300, seed=2)
    cfg = PartitionConfig(n_clients=6, classes_per_client=9, big_clients=2,
                          big_quantity=180, small_quantity=45)
    parts = partition(images, labels, cfg)
    (g,) = stack_clients(parts, batch_size=20, uniform=True)
    assert g.cap == 180 and g.size == 6
    np.testing.assert_array_equal(g.client_ids, np.arange(6))


def test_group_capacity_and_steps_guard():
    """Groups smaller than the batch still take >= 1 local step/epoch."""
    assert group_capacity(45, 20) == 60
    assert group_capacity(45, 64) == 64        # rounded up to one batch
    assert group_capacity(0, 20) == 20
    assert steps_per_epoch(60, 20) == 3
    assert steps_per_epoch(45, 64) == 1        # guarded against 0
    assert steps_per_epoch(0, 20) == 1


def test_small_group_trains_at_least_one_step():
    """A 45-sample client under a 64-sample batch must still produce a
    local update (effective batch clamps to the capacity)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.data.synthetic import make_dataset as mk
    from repro.fl.client import local_train
    from repro.models.cnn import init_cnn

    images, labels = mk(5, seed=9)
    im, lb = jnp.asarray(images[:45]), jnp.asarray(labels[:45])
    g = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    p, _ = local_train(g, im, lb, jnp.int32(45), jax.random.PRNGKey(1),
                       epochs=1, batch_size=64,
                       steps_per_epoch=steps_per_epoch(45, 64), lr=0.1)
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(p), jax.tree.leaves(g)))
    assert moved > 0.0


# --------------------------------------------------------------------------
# aggregation
# --------------------------------------------------------------------------

def test_fedavg_weighted_mean():
    a = {"w": jnp.ones((3, 3)), "b": jnp.zeros((3,))}
    b = {"w": jnp.zeros((3, 3)), "b": jnp.ones((3,))}
    out = fedavg([a, b], [3.0, 1.0])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)
    np.testing.assert_allclose(np.asarray(out["b"]), 0.25)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 100))
def test_fedavg_identity_and_convexity(n, seed):
    rng = np.random.default_rng(seed)
    models = [{"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
              for _ in range(n)]
    weights = rng.uniform(0.1, 5.0, n).tolist()
    out = fedavg(models, weights)
    stacked = np.stack([np.asarray(m["w"]) for m in models])
    lo, hi = stacked.min(0), stacked.max(0)
    w = np.asarray(out["w"])
    assert (w >= lo - 1e-5).all() and (w <= hi + 1e-5).all()
    same = fedavg([models[0]] * 3, [1.0, 2.0, 3.0])
    np.testing.assert_allclose(np.asarray(same["w"]),
                               np.asarray(models[0]["w"]), rtol=1e-6)


def test_global_loss_eq3():
    losses = jnp.array([1.0, 3.0])
    weights = jnp.array([1.0, 1.0])
    assert float(global_loss(losses, weights)) == pytest.approx(2.0)


# --------------------------------------------------------------------------
# selection
# --------------------------------------------------------------------------

def test_ccs_fuzzy_picks_top():
    ev = jnp.array([5.0, 50.0, 20.0, 90.0, 1.0])
    mask = ccs_fuzzy_select(ev, 2)
    assert np.where(np.asarray(mask))[0].tolist() == [1, 3]


def test_ccs_random_count_and_distribution():
    key = jax.random.PRNGKey(0)
    counts = np.zeros(10)
    for i in range(200):
        key, sub = jax.random.split(key)
        mask = np.asarray(ccs_random_select(sub, 10, 3))
        assert mask.sum() == 3
        counts += mask
    assert counts.min() > 20           # every client gets picked sometimes


def test_dcs_respects_range():
    # two separated clusters of 5; top_m=1 per range => 2 selected
    pos = jnp.concatenate([jnp.zeros(5), jnp.full((5,), 900.0)])
    ev = jnp.arange(10, dtype=jnp.float32) + 1
    mask = np.asarray(dcs_select(pos, ev, comm_range=100.0, top_m=1,
                                 e_tau=0.0))
    assert mask.sum() == 2
    assert mask[4] == 1 and mask[9] == 1


# --------------------------------------------------------------------------
# mobility / network / timing
# --------------------------------------------------------------------------

def test_mobility_stays_on_road():
    mob = FreewayMobility(MobilityConfig(n_vehicles=20, seed=3))
    for t in (0.0, 10.0, 1000.0):
        x = mob.positions(t)
        assert ((x >= 0) & (x < 1000.0)).all()


def test_mobility_extreme_clusters():
    cfg = MobilityConfig(n_vehicles=20, distribution="extreme", seed=1)
    rank = np.arange(20)
    mob = FreewayMobility(cfg, quality_rank=rank)
    x = mob.positions(0.0)
    assert (x[rank[:10]] < 200.0).all()
    assert (x[rank[10:]] > 800.0).all()


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 1e5), st.floats(0.1, 5.0), st.integers(0, 20))
def test_mobility_jitter_displacement_bounded(t, jitter, seed):
    """The speed jitter is a sinusoid integrated in closed form, so its
    displacement contribution stays bounded for all t (it must NOT grow
    linearly with elapsed time as the pre-fix ``(v + jitter(t)) * t``
    form did)."""
    from repro.fl.mobility import _JITTER_PERIOD_S
    mob = FreewayMobility(MobilityConfig(n_vehicles=8, speed_jitter=jitter,
                                         seed=seed))
    drift = mob.displacement_m(t) - mob.speeds * t
    bound = 2.0 * jitter * _JITTER_PERIOD_S
    assert np.all(np.abs(drift) <= bound + 1e-6), (t, drift)
    # positions are the wrapped displacement
    np.testing.assert_allclose(
        mob.positions(t),
        np.mod(mob.x0 + mob.displacement_m(t), 1000.0))


def test_mobility_displacement_zero_at_t0():
    mob = FreewayMobility(MobilityConfig(n_vehicles=8, seed=5))
    np.testing.assert_allclose(mob.displacement_m(0.0), 0.0, atol=1e-12)
    np.testing.assert_allclose(mob.positions(0.0),
                               np.mod(mob.x0, 1000.0))


def test_network_rate_bounds_and_ordering():
    net = CellularNetwork(NetworkConfig(seed=0))
    pos = np.linspace(0, 1000, 200)
    rate = net.true_rate_bps(pos)
    assert rate.min() >= 0.24e6 * 0.3          # shadowing slack
    assert rate.max() <= 10.4e6 * 3.0
    # predictor preserves ordering (Spearman) — the paper's §5.1 criterion
    pred = net.predicted_throughput(pos)
    def rank(a):
        return np.argsort(np.argsort(a))
    rho = np.corrcoef(rank(rate), rank(pred))[0, 1]
    assert rho > 0.6, rho


def test_timing_eq6_scaling():
    cfg = TimingConfig(epochs=30, batch_size=20, b_exe_s=0.06)
    t = training_time_s(cfg, np.array([1.0]), np.array([4500]))
    assert t[0] == pytest.approx(30 * 4500 * 0.06 / 20)
    # doubling capability ratio doubles the time; more samples cost more
    t2 = training_time_s(cfg, np.array([2.0]), np.array([4500]))
    assert t2[0] == pytest.approx(2 * t[0])
    ok = completes_before_deadline(TimingConfig(deadline_s=1e9),
                                   t, np.array([1.0]))
    assert ok.all()


# --------------------------------------------------------------------------
# end-to-end round
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_fl_round_end_to_end():
    from repro.fl.rounds import FLSimConfig, FLSimulation
    cfg = FLSimConfig(
        scheme="dcs", n_rounds=2, local_epochs=1, samples_per_class=260,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9),
        mobility=MobilityConfig(n_vehicles=10),
    )
    sim = FLSimulation(cfg)
    hist = sim.run(2)
    assert len(hist) == 2
    assert 0.0 <= hist[-1]["accuracy"] <= 1.0
    assert hist[-1]["n_selected"] >= 1
    # DCS accounting: DSRC latency, no cloud state stream
    assert hist[0]["state_time_s"] < 0.2 * 10 * cfg.deadline_s \
        / cfg.state_interval_s


# --------------------------------------------------------------------------
# FedProx
# --------------------------------------------------------------------------

def test_fedprox_pulls_towards_global():
    """With large prox_mu the local update stays near the global model;
    with mu=0 it drifts further (FedProx [17], cited by the paper)."""
    import jax
    import jax.numpy as jnp
    from repro.fl.client import local_train
    from repro.models.cnn import init_cnn
    from repro.configs.mnist_cnn import CONFIG as CNN_CFG
    from repro.data.synthetic import make_dataset

    images, labels = make_dataset(20, seed=5)
    images, labels = jnp.asarray(images[:100]), jnp.asarray(labels[:100])
    g = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    key = jax.random.PRNGKey(1)

    def dist(a, b):
        return float(sum(jnp.sum(jnp.square(x - y)) for x, y in
                         zip(jax.tree.leaves(a), jax.tree.leaves(b))))

    kw = dict(epochs=2, batch_size=20, steps_per_epoch=5, lr=0.1)
    p_plain, _ = local_train(g, images, labels, jnp.int32(100), key, **kw)
    p_prox, _ = local_train(g, images, labels, jnp.int32(100), key,
                            prox_mu=10.0, **kw)
    assert dist(p_prox, g) < dist(p_plain, g)


def test_mobility_deterministic_in_t():
    mob = FreewayMobility(MobilityConfig(n_vehicles=10, seed=4))
    np.testing.assert_array_equal(mob.positions(12.5), mob.positions(12.5))


def test_staleness_experiment_sane():
    """tau=0 centralized selection is ideal; staleness induces regret;
    DCS stays low-regret with fresh local state."""
    from benchmarks.staleness import bench_staleness
    rows = {r.split(",")[0]: float(r.split(",")[1])
            for r in bench_staleness()}
    assert abs(rows["staleness_ccs_regret@tau=0"]) < 1e-6
    assert rows["staleness_ccs_regret@tau=30"] > 0.02
    assert rows["staleness_dcs_regret"] < rows["staleness_ccs_regret@tau=30"]
