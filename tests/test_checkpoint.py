"""Checkpoint format tests (ISSUE 10): the v2 self-describing state
format's bit-identity contract (property-tested over nested pytrees,
bfloat16 included), the legacy (params, opt_state, step) API's
validation (treedef + shape + dtype, with the offending key path), the
checksum/corruption detection, ``RoundCheckpointer`` cadence /
retention / corrupt-skip recovery, and ``write_atomic``'s interrupted
write guarantee."""
import collections
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.ioutil import sha256_file, write_atomic, write_atomic_json
from repro.launch.faults import flip_byte, truncate_file
from repro.train.checkpoint import (CheckpointCorruptError,
                                    CheckpointCorruptWarning,
                                    RoundCheckpointer, is_valid_checkpoint,
                                    load_checkpoint, load_state,
                                    save_checkpoint, save_state)


# --------------------------------------------------------------------------
# v2 state format: property-tested bit-identity over nested pytrees
# --------------------------------------------------------------------------

_LEAF_DTYPES = (np.float32, np.int32, jnp.bfloat16)


def _rand_tree(rng: np.random.Generator, depth: int = 0):
    """A random pytree: dicts / lists / tuples / None / array leaves of
    f32 / bf16 / int32 / Python scalars / empty subtrees."""
    pick = int(rng.integers(0, 10 if depth < 3 else 6))
    if pick == 0:
        return None
    if pick == 1:
        return int(rng.integers(-10**9, 10**9))
    if pick == 2:
        return float(rng.standard_normal())
    if pick == 3:
        return bool(rng.integers(0, 2))
    if pick <= 5:
        shape = tuple(int(s) for s in
                      rng.integers(0, 4, size=int(rng.integers(0, 3))))
        dtype = _LEAF_DTYPES[int(rng.integers(0, len(_LEAF_DTYPES)))]
        if dtype is np.int32:
            return rng.integers(-2**31, 2**31 - 1,
                                size=shape).astype(np.int32)
        vals = rng.standard_normal(shape)
        if dtype is jnp.bfloat16:
            return np.asarray(jnp.asarray(vals, jnp.bfloat16))
        return vals.astype(np.float32)
    n = int(rng.integers(0, 4))          # containers, possibly empty
    if pick <= 7:
        return {f"k{i}": _rand_tree(rng, depth + 1) for i in range(n)}
    if pick == 8:
        return [_rand_tree(rng, depth + 1) for _ in range(n)]
    return tuple(_rand_tree(rng, depth + 1) for _ in range(n))


def _assert_same_tree(a, b, path=""):
    """Exact structural + bitwise equality (dtype, shape, raw bytes)."""
    where = path or "<root>"
    if a is None:
        assert b is None, where
    elif isinstance(a, dict):
        assert isinstance(b, dict) and sorted(b) == sorted(a), where
        for k in a:
            _assert_same_tree(a[k], b[k], f"{where}/{k}")
    elif isinstance(a, (list, tuple)):
        assert type(b) in (type(a), tuple if isinstance(a, tuple)
                           else list), where
        assert len(a) == len(b), where
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_same_tree(x, y, f"{where}/{i}")
    elif isinstance(a, (bool, int, float)):
        assert type(a) is type(b) and a == b, where
    else:
        aa, bb = np.asarray(a), np.asarray(b)
        assert aa.dtype == bb.dtype, f"{where}: {aa.dtype} vs {bb.dtype}"
        assert aa.shape == bb.shape, f"{where}: {aa.shape} vs {bb.shape}"
        assert aa.tobytes() == bb.tobytes(), f"{where}: payload differs"


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6))
def test_state_roundtrip_bit_identical(seed):
    """Property: save_state -> load_state is the identity, bit-for-bit,
    for arbitrary nested containers, dtypes and Python scalars."""
    import tempfile
    rng = np.random.default_rng(seed)
    tree = {"t": _rand_tree(rng), "u": _rand_tree(rng)}
    with tempfile.TemporaryDirectory() as d:
        save_state(d, tree, extra={"seed": seed})
        got, extra = load_state(d)
    _assert_same_tree(tree, got)
    assert extra == {"seed": seed}


def test_bf16_bit_identity(tmp_path):
    """bfloat16 cannot ride npz's native dtype descriptors — the raw
    byte-buffer encoding must carry it bit-exactly (NaNs included)."""
    raw = np.arange(64, dtype=np.uint16)         # every pattern distinct
    arr = raw.view(jnp.bfloat16)
    save_state(str(tmp_path), {"w": arr})
    got, _ = load_state(str(tmp_path))
    assert np.asarray(got["w"]).dtype == jnp.bfloat16
    assert np.asarray(got["w"]).tobytes() == arr.tobytes()


def test_empty_and_scalar_leaves(tmp_path):
    state = {"empty_dict": {}, "empty_list": [], "empty_tuple": (),
             "none": None, "i": 7, "f": 0.1, "b": True,
             "empty_arr": np.zeros((0, 3), np.float32)}
    save_state(str(tmp_path), state)
    got, _ = load_state(str(tmp_path))
    _assert_same_tree(state, got)
    assert type(got["i"]) is int and type(got["f"]) is float
    assert type(got["b"]) is bool


def test_object_dtype_rejected(tmp_path):
    with pytest.raises(TypeError, match="object-dtype"):
        save_state(str(tmp_path), {"bad": np.array(["a", None],
                                                   dtype=object)})


# --------------------------------------------------------------------------
# legacy API: restore-into-template validation
# --------------------------------------------------------------------------

Opt = collections.namedtuple("Opt", ["mu", "count"])


def _params():
    return {"dense": {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                      "b": np.zeros(3, np.float32)}}


def test_legacy_roundtrip_namedtuple_opt(tmp_path):
    opt = Opt(mu={"dense": {"w": np.ones((2, 3), np.float32),
                            "b": np.ones(3, np.float32)}},
              count=np.int32(4))
    save_checkpoint(str(tmp_path), _params(), opt, step=11,
                    extra={"tag": "x"})
    params, opt2, step = load_checkpoint(str(tmp_path), _params(), opt)
    assert step == 11
    assert isinstance(opt2, Opt)         # namedtuple class preserved
    _assert_same_tree(jax.device_get(_params()), jax.device_get(params))
    np.testing.assert_array_equal(np.asarray(opt2.count), 4)


def test_legacy_dtype_mismatch_names_path(tmp_path):
    save_checkpoint(str(tmp_path), _params())
    tmpl = _params()
    tmpl["dense"]["w"] = tmpl["dense"]["w"].astype(np.float16)
    with pytest.raises(ValueError, match=r"dtype mismatch for "
                                         r"params/dense/w"):
        load_checkpoint(str(tmp_path), tmpl)


def test_legacy_shape_mismatch_names_path(tmp_path):
    save_checkpoint(str(tmp_path), _params())
    tmpl = _params()
    tmpl["dense"]["b"] = np.zeros(4, np.float32)
    with pytest.raises(ValueError, match=r"shape mismatch for "
                                         r"params/dense/b"):
        load_checkpoint(str(tmp_path), tmpl)


def test_legacy_treedef_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), _params())
    tmpl = _params()
    tmpl["extra_layer"] = np.zeros(2, np.float32)
    with pytest.raises(ValueError, match="treedef mismatch"):
        load_checkpoint(str(tmp_path), tmpl)


def test_legacy_missing_opt_raises(tmp_path):
    save_checkpoint(str(tmp_path), _params())     # no opt stored
    with pytest.raises(ValueError, match="no opt state"):
        load_checkpoint(str(tmp_path), _params(),
                        opt_like={"m": np.zeros(1, np.float32)})


# --------------------------------------------------------------------------
# corruption detection: the manifest is the commit point
# --------------------------------------------------------------------------

def test_checksum_detects_flipped_byte(tmp_path):
    save_state(str(tmp_path), {"w": np.arange(32, dtype=np.float32)})
    assert is_valid_checkpoint(str(tmp_path))
    flip_byte(str(tmp_path / "arrays.npz"), 10)
    assert not is_valid_checkpoint(str(tmp_path))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        load_state(str(tmp_path))


def test_truncated_manifest_detected(tmp_path):
    save_state(str(tmp_path), {"w": np.zeros(4, np.float32)})
    truncate_file(str(tmp_path / "manifest.json"), 20)
    with pytest.raises(CheckpointCorruptError, match="unreadable manifest"):
        load_state(str(tmp_path))


def test_missing_manifest_is_half_written(tmp_path):
    """A kill between the arrays write and the manifest write leaves no
    manifest — readers must treat that as 'no checkpoint here'."""
    save_state(str(tmp_path), {"w": np.zeros(4, np.float32)})
    os.unlink(tmp_path / "manifest.json")
    with pytest.raises(CheckpointCorruptError, match="no manifest"):
        load_state(str(tmp_path))


def test_format_version_mismatch_detected(tmp_path):
    save_state(str(tmp_path), {"w": np.zeros(4, np.float32)})
    man = json.loads((tmp_path / "manifest.json").read_text())
    man["format_version"] = 1
    (tmp_path / "manifest.json").write_text(json.dumps(man))
    with pytest.raises(CheckpointCorruptError, match="format_version"):
        load_state(str(tmp_path))


# --------------------------------------------------------------------------
# RoundCheckpointer: cadence, retention, corrupt-skip recovery
# --------------------------------------------------------------------------

def _state(rnd):
    return {"r": np.full(3, rnd, np.int32)}


def test_round_cadence_and_retention(tmp_path):
    ck = RoundCheckpointer(str(tmp_path), every=3, keep=2)
    assert [r for r in range(9) if ck.due(r)] == [2, 5, 8]
    for r in range(5):
        ck.save_round(r, _state(r), extra={"next_round": r + 1})
    assert ck.rounds_on_disk() == [3, 4]          # pruned beyond keep
    rnd, state, extra = ck.latest_good()
    assert rnd == 4 and extra["next_round"] == 5
    np.testing.assert_array_equal(state["r"], 4)
    ck.clear()
    assert ck.rounds_on_disk() == []


def test_latest_good_skips_corrupt_with_warning(tmp_path):
    ck = RoundCheckpointer(str(tmp_path), keep=5)
    for r in range(3):
        ck.save_round(r, _state(r))
    flip_byte(os.path.join(ck.path_for(2), "arrays.npz"), 10)
    os.unlink(os.path.join(ck.path_for(1), "manifest.json"))
    with pytest.warns(CheckpointCorruptWarning):
        rnd, state, _ = ck.latest_good()
    assert rnd == 0                               # newest *good* snapshot
    np.testing.assert_array_equal(state["r"], 0)


def test_latest_good_none_when_all_corrupt(tmp_path):
    ck = RoundCheckpointer(str(tmp_path), keep=5)
    ck.save_round(0, _state(0))
    flip_byte(os.path.join(ck.path_for(0), "arrays.npz"), 10)
    with pytest.warns(CheckpointCorruptWarning):
        assert ck.latest_good() is None
    assert RoundCheckpointer(str(tmp_path / "nothing")).latest_good() \
        is None


def test_round_checkpointer_validates_args(tmp_path):
    with pytest.raises(ValueError):
        RoundCheckpointer(str(tmp_path), every=0)
    with pytest.raises(ValueError):
        RoundCheckpointer(str(tmp_path), keep=0)


# --------------------------------------------------------------------------
# write_atomic: a failed/interrupted write never tears the target
# --------------------------------------------------------------------------

def test_write_atomic_interrupted_leaves_target_intact(tmp_path,
                                                       monkeypatch):
    """Kill the write at the rename (the last possible moment): the
    previous contents must survive untouched, and a retry lands the new
    payload completely."""
    target = tmp_path / "artifact.csv"
    write_atomic(target, "old,complete,contents\n")
    real_replace = os.replace

    def dying_replace(src, dst):
        raise OSError("simulated crash at commit")

    monkeypatch.setattr(os, "replace", dying_replace)
    with pytest.raises(OSError, match="simulated crash"):
        write_atomic(target, "new,partial?\n")
    assert target.read_text() == "old,complete,contents\n"
    monkeypatch.setattr(os, "replace", real_replace)
    write_atomic(target, "new,complete,contents\n")
    assert target.read_text() == "new,complete,contents\n"


def test_write_atomic_json_and_checksum(tmp_path):
    p = tmp_path / "bench.json"
    write_atomic_json(p, {"metric": 1.5, "n": [1, 2]}, indent=1)
    assert json.loads(p.read_text()) == {"metric": 1.5, "n": [1, 2]}
    digest = sha256_file(p)
    assert digest == sha256_file(p) and len(digest) == 64
