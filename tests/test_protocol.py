"""shard_map selection protocols on a small debug mesh: correctness vs the
single-device reference, and the collective-bytes asymmetry in lowered HLO."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.protocol import (make_ccs_fuzzy_gather, make_ccs_state_gather,
                                 make_dcs_neighbor_exchange)
from repro.kernels import ref as kref

N_DEV = len(jax.devices())


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((N_DEV,), ("data",))


def test_ccs_fuzzy_gather_matches_topk(mesh):
    n = 8 * N_DEV
    ev = jax.random.uniform(jax.random.PRNGKey(0), (n,)) * 100
    fn = jax.jit(make_ccs_fuzzy_gather(mesh, n_clients=5))
    mask = np.asarray(fn(ev))
    want = np.zeros(n, np.int32)
    want[np.argsort(-np.asarray(ev))[:5]] = 1
    np.testing.assert_array_equal(mask, want)


def test_ccs_state_gather_runs(mesh):
    n, sd = 8 * N_DEV, 8
    states = jax.random.uniform(jax.random.PRNGKey(1), (n, sd))
    fn = jax.jit(make_ccs_state_gather(mesh, FuzzyEvaluator(), 5, sd))
    mask = np.asarray(fn(states))
    assert mask.sum() == 5


def test_dcs_exchange_matches_reference_when_local(mesh):
    """With ranges shorter than a shard's road segment, the sharded
    neighbour exchange equals the global reference election."""
    n = 16 * N_DEV
    # vehicles sorted along the road => shard = contiguous segment
    pos = jnp.sort(jax.random.uniform(jax.random.PRNGKey(2), (n,)) * 1000)
    ev = jax.random.uniform(jax.random.PRNGKey(3), (n,)) * 100
    seg = 1000.0 / N_DEV if N_DEV > 1 else 1000.0
    rng = min(150.0, seg * 0.9)
    fn = jax.jit(make_dcs_neighbor_exchange(mesh, comm_range=rng, top_m=2,
                                            e_tau=30.0))
    mask = np.asarray(fn(pos, ev))
    ref = np.asarray(kref.neighbor_elect_ref(pos, ev, comm_range=rng,
                                             top_m=2, e_tau=30.0))
    np.testing.assert_array_equal(mask, ref)


def _collective_bytes(lowered_text: str) -> int:
    total = 0
    for m in re.finditer(r'"?(all-gather|collective-permute|all-reduce)'
                         r'(?:-start)?"?[^\n]*', lowered_text):
        pass
    return total


def test_protocol_collective_asymmetry(mesh):
    """The paper's Eq. 5 claim restated in HLO: the state-gather protocol
    moves O(N * state_dim) per device, the DCS exchange O(window).  Compare
    compiled collective op output sizes."""
    if N_DEV < 2:
        pytest.skip("needs >1 device to materialize collectives")
    n, sd = 64 * N_DEV, 25
    states = jax.ShapeDtypeStruct((n, sd), jnp.float32)
    ev = jax.ShapeDtypeStruct((n,), jnp.float32)
    pos = jax.ShapeDtypeStruct((n,), jnp.float32)

    from repro.launch import hlo_cost
    g = jax.jit(make_ccs_state_gather(mesh, FuzzyEvaluator(), 5, sd)) \
        .lower(states).compile()
    d = jax.jit(make_dcs_neighbor_exchange(mesh, comm_range=10.0, top_m=2,
                                           e_tau=30.0, window=8)) \
        .lower(pos, ev).compile()
    cg = hlo_cost.analyze(g.as_text()).collective_bytes
    cd = hlo_cost.analyze(d.as_text()).collective_bytes
    assert cd < cg, (cd, cg)
