"""End-to-end behaviour tests for the paper's system.

The headline reproduction property (paper §6.2): on the non-iid vehicular
dataset, DCS selects near the centralized budget of clients without any
server-side state collection, and the fuzzy evaluation of selected clients
beats the population average (the selection is 'biased' the right way).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fuzzy import FuzzyEvaluator
from repro.core.selection import dcs_select, ccs_fuzzy_select
from repro.fl.mobility import FreewayMobility, MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig, FLSimulation


def _sim(scheme, seed=0, rounds=1):
    return FLSimulation(FLSimConfig(
        scheme=scheme, n_rounds=rounds, local_epochs=1,
        samples_per_class=260,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=9),
        mobility=MobilityConfig(n_vehicles=10, seed=seed), seed=seed))


def _round0_state(sim):
    """Round-0 positions/evals from the staged selection prefix (the
    host-driven ``sim._features`` path this file used pre-ISSUE-3)."""
    state = jax.device_get(sim.selection_state(0))
    return np.asarray(state["pos"]), jnp.asarray(state["evals"])


def test_dcs_selected_count_tracks_paper():
    """Paper: DCS averages ~5 selected on the 30-vehicle road with top_m=2
    per 200 m.  On our 10-vehicle debug road, DCS must select >=1 and <=
    top_m * ceil(road/range) vehicles each round."""
    sim = _sim("dcs")
    pos, evals = _round0_state(sim)
    mask = np.asarray(dcs_select(jnp.asarray(pos), evals,
                                 comm_range=200.0, top_m=2, e_tau=30.0))
    assert 1 <= mask.sum() <= 2 * int(np.ceil(1000 / 200.0)) + 2


def test_dcs_selects_better_than_average():
    sim = _sim("dcs", seed=1)
    pos, evals = _round0_state(sim)
    evals = np.asarray(evals)
    mask = np.asarray(dcs_select(jnp.asarray(pos), jnp.asarray(evals),
                                 comm_range=200.0, top_m=2, e_tau=30.0))
    if mask.sum() and mask.sum() < len(evals):
        assert evals[mask > 0].mean() >= evals.mean() - 1e-6


def test_dcs_vs_ccs_fuzzy_selection_overlap():
    """DCS approximates centralized fuzzy selection (the paper's headline):
    selected sets overlap substantially under uniform vehicle placement."""
    sim = _sim("dcs", seed=2)
    pos, evals = _round0_state(sim)
    m_dcs = np.asarray(dcs_select(jnp.asarray(pos), evals,
                                  comm_range=200.0, top_m=2, e_tau=30.0))
    m_ccs = np.asarray(ccs_fuzzy_select(evals, int(m_dcs.sum())))
    inter = ((m_dcs > 0) & (m_ccs > 0)).sum()
    assert inter >= max(1, int(0.4 * m_dcs.sum()))


def test_grouped_engine_table3_skew_round():
    """The batched engine on a Table-3-shaped quantity skew forms one
    capacity group per quantity bucket and completes a round with the
    skewed small clients eligible to aggregate."""
    sim = FLSimulation(FLSimConfig(
        scheme="ccs-fuzzy", n_rounds=1, local_epochs=1,
        samples_per_class=300, probe_samples=64,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=200, small_quantity=45,
                                  classes_per_client=9),
        mobility=MobilityConfig(n_vehicles=10, seed=0), seed=0))
    assert [g.cap for g in sim.groups] == [200, 60]
    assert sum(g.size for g in sim.groups) == 10
    sim.warmup()
    row = sim.run_round(0)
    assert 0.0 <= row["accuracy"] <= 1.0
    assert row["n_selected"] >= 1
    assert row["n_aggregated"] <= row["n_selected"]


@pytest.mark.slow
def test_one_round_improves_over_init():
    # 4 rounds of ~4 clients x 6 local steps: enough to clear random (0.1)
    # decisively under any per-round key schedule
    sim = _sim("dcs", seed=3, rounds=4)
    h = sim.run(4)
    assert h[-1]["accuracy"] > 0.15        # 10 classes, random = 0.1
