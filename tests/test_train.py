"""Training substrate: AdamW/schedules, grad-accum equivalence,
checkpoint round-trip, CNN training sanity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, scaled_down
from repro.configs.base import ShapeConfig
from repro.configs.mnist_cnn import CONFIG as CNN_CFG
from repro.models import registry as R
from repro.models.cnn import cnn_loss, count_params, init_cnn
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optim import (OptConfig, adamw_init, adamw_update,
                               schedule_lr, sgd_update)
from repro.train.step import make_train_step


def test_cnn_param_count_matches_paper():
    params = init_cnn(jax.random.PRNGKey(0), CNN_CFG)
    n = count_params(params)
    assert abs(n - 1_663_370) < 5_000         # paper: ~1.66M


def test_cnn_learns_synthetic():
    from repro.data.synthetic import make_dataset
    images, labels = make_dataset(40, seed=0)
    images, labels = jnp.asarray(images), jnp.asarray(labels)
    params = init_cnn(jax.random.PRNGKey(1), CNN_CFG)
    loss0, m0 = cnn_loss(params, images, labels)

    @jax.jit
    def step(p):
        (l, m), g = jax.value_and_grad(cnn_loss, has_aux=True)(
            p, images, labels)
        return sgd_update(p, g, 0.1), l

    for _ in range(40):
        params, l = step(params)
    loss1, m1 = cnn_loss(params, images, labels)
    assert float(loss1) < float(loss0) * 0.5
    assert float(m1["acc"]) > 0.7


def test_schedules():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    schedule="cosine")
    lr_w = float(schedule_lr(cfg, jnp.int32(5)))
    lr_p = float(schedule_lr(cfg, jnp.int32(10)))
    lr_e = float(schedule_lr(cfg, jnp.int32(100)))
    assert lr_w < lr_p and lr_e < lr_p
    assert lr_e == pytest.approx(1e-4, rel=0.05)          # min_lr_frac
    wsd = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    schedule="wsd")
    lr_stable = float(schedule_lr(wsd, jnp.int32(50)))
    assert lr_stable == pytest.approx(1e-3, rel=1e-5)     # stable plateau
    lr_decay = float(schedule_lr(wsd, jnp.int32(99)))
    assert lr_decay < lr_stable


def test_adamw_step_moves_params():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = adamw_init(params)
    p2, st2, m = adamw_update(OptConfig(warmup_steps=0), grads, st, params)
    assert int(st2["step"]) == 1
    assert float(m["grad_norm"]) > 0
    assert not np.allclose(np.asarray(p2["w"]), 1.0)


def test_grad_accum_equivalence():
    """ga=2 over a batch == ga=1 over the same batch (same grads up to
    numerics), since microbatch losses are averaged."""
    cfg = scaled_down(get_arch("gemma-2b"))
    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    sh1 = ShapeConfig("a", 32, 4, "train", grad_accum=1)
    sh2 = ShapeConfig("b", 32, 4, "train", grad_accum=2)
    batch = R.make_concrete_batch(cfg, sh1, key, "train")
    opt = OptConfig(lr=1e-2, grad_clip=0.0, weight_decay=0.0)
    s1 = make_train_step(cfg, sh1, opt)
    s2 = make_train_step(cfg, sh2, opt)
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p2, _, m2 = s2(params, adamw_init(params), batch)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d


def test_checkpoint_roundtrip():
    cfg = scaled_down(get_arch("gemma-2b"))
    key = jax.random.PRNGKey(0)
    params = R.init_params(key, cfg)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt, step=7, extra={"arch": cfg.name})
        p2, o2, step = load_checkpoint(d, params, opt)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
