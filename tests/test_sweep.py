"""Sweep-harness tests (ISSUE 3): CSV schema golden test, bitwise
determinism of a 2-seed x 2-scheme sweep across runs, and aggregation
consistency.  All on a tiny fast profile so the fast CI tier covers the
acceptance criteria."""
import numpy as np
import pytest

from repro.fl.mobility import MobilityConfig
from repro.fl.partition import PartitionConfig
from repro.fl.rounds import FLSimConfig
from repro.launch.sweep import (CSV_COLUMNS, aggregate_rows, rows_to_csv,
                                sweep)

SCHEMES = ("dcs", "random")
SEEDS = (0, 1)
ROUNDS = 2


def _tiny(scheme, classes, dist, seed):
    return FLSimConfig(
        scheme=scheme, local_epochs=1, samples_per_class=260,
        probe_samples=64, seed=seed,
        partition=PartitionConfig(n_clients=10, big_clients=3,
                                  big_quantity=120, small_quantity=40,
                                  classes_per_client=classes, seed=seed),
        mobility=MobilityConfig(n_vehicles=10, distribution=dist,
                                seed=seed))


def _run_sweep():
    rows = sweep(SCHEMES, (9,), ("uniform",), seeds=SEEDS, rounds=ROUNDS,
                 cfg_fn=_tiny)
    return rows, rows_to_csv(rows)


@pytest.fixture(scope="module")
def sweep_result():
    return _run_sweep()


def test_csv_schema_golden(sweep_result):
    """The tidy CSV header is pinned: cell identity + per-seed metrics +
    across-seed mean/std columns, in this exact order."""
    rows, csv_text = sweep_result
    lines = csv_text.strip().split("\n")
    assert lines[0] == ",".join(CSV_COLUMNS)
    assert lines[0] == (
        "round,scheme,seed,classes_per_client,distribution,"
        "churn_rate,staleness_lambda,agg_cadence_s,accuracy,"
        "n_selected,n_aggregated,n_straggler,n_active,stale_frac,"
        "n_effective,rounds_behind_hist,mean_eval_selected,"
        "state_bytes,upload_bytes,state_time_s,comm_time_s,"
        "accuracy_mean,accuracy_std,n_selected_mean,n_selected_std,"
        "n_straggler_mean,n_straggler_std")
    # one row per (scheme, seed, round), every cell fully populated
    assert len(lines) == 1 + len(SCHEMES) * len(SEEDS) * ROUNDS
    for line in lines[1:]:
        assert len(line.split(",")) == len(CSV_COLUMNS)
    assert {r["scheme"] for r in rows} == set(SCHEMES)
    assert {r["seed"] for r in rows} == set(SEEDS)
    for r in rows:
        assert 0.0 <= r["accuracy"] <= 1.0
        assert r["n_aggregated"] <= r["n_selected"]


def test_sweep_bitwise_deterministic(sweep_result):
    """Running the identical 2-seed x 2-scheme sweep twice yields a
    byte-identical CSV (fixed row order, fixed float formatting, pure
    staged prefix underneath)."""
    _, first = sweep_result
    _, second = _run_sweep()
    assert first == second


def test_aggregate_mean_std_consistent(sweep_result):
    """The mean/std columns equal numpy aggregation of the per-seed rows
    within each (round, scheme, classes, distribution) group."""
    rows, _ = sweep_result
    for scheme in SCHEMES:
        for rnd in range(ROUNDS):
            grp = [r for r in rows
                   if r["scheme"] == scheme and r["round"] == rnd]
            assert len(grp) == len(SEEDS)
            accs = np.asarray([r["accuracy"] for r in grp])
            for r in grp:
                assert r["accuracy_mean"] == pytest.approx(accs.mean())
                assert r["accuracy_std"] == pytest.approx(
                    accs.std(ddof=1))         # sample std: seeds are a
                                              # sample, not the population


def test_aggregate_rows_groups_by_cell():
    """Aggregation groups strictly by (round, scheme, classes, dist) —
    other cells' seeds never leak into a group's statistics."""
    rows = [
        {"round": 0, "scheme": "dcs", "classes_per_client": 9,
         "distribution": "uniform", "seed": s, "accuracy": a,
         "n_selected": 5, "n_straggler": 0}
        for s, a in ((0, 0.2), (1, 0.4))
    ] + [
        {"round": 0, "scheme": "random", "classes_per_client": 9,
         "distribution": "uniform", "seed": 0, "accuracy": 1.0,
         "n_selected": 5, "n_straggler": 0}
    ]
    agg = aggregate_rows(rows)
    dcs = [r for r in agg if r["scheme"] == "dcs"]
    assert all(r["accuracy_mean"] == pytest.approx(0.3) for r in dcs)
    assert all(r["accuracy_std"] == pytest.approx(np.std([0.2, 0.4],
                                                         ddof=1))
               for r in dcs)
    rnd = [r for r in agg if r["scheme"] == "random"]
    assert rnd[0]["accuracy_mean"] == pytest.approx(1.0)
    assert rnd[0]["accuracy_std"] == 0.0       # single seed: no spread
