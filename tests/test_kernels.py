"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, with shape/dtype
sweeps, plus fast-path (jnp chunked) vs oracle equivalence."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rules import build_rule_table
from repro.kernels import ref as kref
from repro.kernels.fuzzy_eval import fuzzy_eval_pallas
from repro.kernels.neighbor_elect import neighbor_elect_pallas
from repro.kernels.wkv6 import wkv6_pallas
from repro.models.rwkv6 import wkv6_scan


# --------------------------------------------------------------------------
# WKV6
# --------------------------------------------------------------------------

def _wkv_inputs(b, t, h, n, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, t, h, n), dtype)
    k = jax.random.normal(ks[1], (b, t, h, n), dtype)
    v = jax.random.normal(ks[2], (b, t, h, n), dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n))) * 0.5
         + 0.45).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, n)) * 0.1).astype(jnp.float32)
    s0 = (jax.random.normal(ks[5], (b, h, n, n)) * 0.1).astype(jnp.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("b,t,h,n", [(1, 32, 1, 64), (2, 128, 3, 64),
                                     (2, 256, 2, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_vs_oracle(b, t, h, n, dtype):
    r, k, v, w, u, s0 = _wkv_inputs(b, t, h, n, dtype)
    y0, sT0 = kref.wkv6_ref(r, k, v, w, u, s0)
    y1, sT1 = wkv6_pallas(r, k, v, w, u, s0, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sT0), np.asarray(sT1),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("t", [64, 256, 512])
def test_wkv6_chunked_scan_vs_oracle(t):
    r, k, v, w, u, s0 = _wkv_inputs(2, t, 2, 64, jnp.float32, seed=3)
    y0, sT0 = kref.wkv6_ref(r, k, v, w, u, s0)
    y1, sT1 = wkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sT0), np.asarray(sT1),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_grad_flows():
    r, k, v, w, u, s0 = _wkv_inputs(1, 64, 1, 64, jnp.float32, seed=4)

    def loss(r_):
        y, _ = wkv6_scan(r_, k, v, w, u, s0)
        return jnp.sum(jnp.square(y))

    g = jax.grad(loss)(r)
    assert not jnp.isnan(g).any()
    assert float(jnp.abs(g).max()) > 0


# --------------------------------------------------------------------------
# fuzzy_eval
# --------------------------------------------------------------------------

@pytest.mark.parametrize("p", [1, 30, 300, 1025])
def test_fuzzy_pallas_vs_oracle(p):
    table, levels = build_rule_table()
    x = jax.random.uniform(jax.random.PRNGKey(p), (p, 4))
    means = jnp.tile(jnp.array([0.15, 0.5, 0.85]), (4, 1))
    sigmas = jnp.full((4, 3), 0.18)
    centers = jnp.linspace(0.0, 100.0, 9)
    e0 = kref.fuzzy_eval_ref(x, means, sigmas, table, levels, centers)
    e1 = fuzzy_eval_pallas(x, means, sigmas, table, levels, centers,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("p", [1, 30, 300, 1025])
def test_fuzzy_pallas_vs_jnp_normalize_raw(p):
    """Eq. 8 folded into the kernel (ISSUE 3): pallas-interpret and the
    jnp reference agree on *raw* feature batches (arbitrary per-column
    scales: |D_i| ~ 1e3, TA ~ 1e7, CC ~ 1, LF ~ 1), and the in-kernel
    normalization equals host-side Eq. 8 + the unnormalized kernel."""
    table, levels = build_rule_table()
    scales = jnp.array([4.5e3, 1.04e7, 1.0, 2.3])
    x = jax.random.uniform(jax.random.PRNGKey(p + 7), (p, 4)) * scales
    means = jnp.tile(jnp.array([0.15, 0.5, 0.85]), (4, 1))
    sigmas = jnp.full((4, 3), 0.18)
    centers = jnp.linspace(0.0, 100.0, 9)
    e_jnp = kref.fuzzy_eval_ref(x, means, sigmas, table, levels, centers,
                                normalize=True)
    e_pal = fuzzy_eval_pallas(x, means, sigmas, table, levels, centers,
                              interpret=True, normalize=True)
    np.testing.assert_allclose(np.asarray(e_jnp), np.asarray(e_pal),
                               atol=1e-3, rtol=1e-4)
    # folded == host-side Eq. 8 (value / column max) + plain kernel
    x_norm = x / jnp.maximum(x.max(axis=0), 1e-9)
    e_host = kref.fuzzy_eval_ref(x_norm, means, sigmas, table, levels,
                                 centers)
    np.testing.assert_allclose(np.asarray(e_jnp), np.asarray(e_host),
                               atol=1e-4, rtol=1e-5)


# --------------------------------------------------------------------------
# neighbor_elect
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,rng,top_m", [(30, 200.0, 2), (300, 200.0, 2),
                                         (1000, 150.0, 3), (257, 50.0, 1)])
def test_elect_pallas_vs_oracle(n, rng, top_m):
    pos = jax.random.uniform(jax.random.PRNGKey(n), (n,)) * 1000.0
    ev = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,)) * 100.0
    s0 = kref.neighbor_elect_ref(pos, ev, comm_range=rng, top_m=top_m,
                                 e_tau=30.0)
    s1 = neighbor_elect_pallas(pos, ev, comm_range=rng, top_m=top_m,
                               e_tau=30.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_elect_topm_bound_per_neighbourhood():
    """In any ``comm_range`` window at most top_m + boundary effects are
    selected; with all vehicles in one point, exactly top_m."""
    n, top_m = 50, 2
    pos = jnp.zeros((n,))
    ev = jnp.arange(n, dtype=jnp.float32)
    sel = kref.neighbor_elect_ref(pos, ev, comm_range=200.0, top_m=top_m,
                                  e_tau=0.0)
    assert int(sel.sum()) == top_m
    # the selected ones are the best evaluations
    assert set(np.where(np.asarray(sel))[0]) == {n - 1, n - 2}


def test_elect_threshold():
    pos = jnp.linspace(0, 1000, 10)
    ev = jnp.full((10,), 10.0)
    sel = kref.neighbor_elect_ref(pos, ev, comm_range=200.0, top_m=2,
                                  e_tau=30.0)
    assert int(sel.sum()) == 0        # nobody clears E_tau


# --------------------------------------------------------------------------
# selective_scan (mamba)
# --------------------------------------------------------------------------

from repro.kernels.selective_scan import selective_scan_pallas


@pytest.mark.parametrize("b,t,di,n", [(1, 64, 256, 16), (2, 128, 256, 16),
                                      (2, 96, 512, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_pallas_vs_oracle(b, t, di, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(ks[0], (b, t, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, di))
                         - 4.0).astype(dtype)
    bmat = jax.random.normal(ks[2], (b, t, n), dtype)
    cmat = jax.random.normal(ks[3], (b, t, n), dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.5)
    h0 = (jax.random.normal(ks[5], (b, di, n)) * 0.1).astype(jnp.float32)
    y0, h0T = kref.selective_scan_ref(x, dt, bmat, cmat, a, h0)
    y1, h1T = selective_scan_pallas(x, dt, bmat, cmat, a, h0,
                                    interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h0T), np.asarray(h1T),
                               atol=tol, rtol=tol)


def test_selective_scan_matches_mamba_layer_math():
    """The kernel oracle agrees with the model-side chunked scan
    (models/mamba.py::_ssm_scan)."""
    from repro.models.mamba import _ssm_scan
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    b, t, di, n = 2, 64, 128, 16
    x = jax.random.normal(ks[0], (b, t, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, di)) - 4.0)
    bmat = jax.random.normal(ks[2], (b, t, n))
    cmat = jax.random.normal(ks[3], (b, t, n))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.5)
    h0 = jnp.zeros((b, di, n))
    y0, hT0 = kref.selective_scan_ref(x, dt, bmat, cmat, a, h0)
    y1, hT1 = _ssm_scan(x, dt, bmat, cmat, a, h0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(hT0), np.asarray(hT1),
                               atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import flash_attention as _flash_jnp


@pytest.mark.parametrize("sq,skv,hq,hkv,dh,causal,window,prefix", [
    (128, 128, 4, 2, 32, True, 0, 0),       # GQA causal
    (256, 256, 4, 1, 64, True, 64, 0),      # MQA sliding window
    (128, 128, 2, 2, 32, True, 0, 32),      # prefix-LM
    (96, 160, 4, 4, 32, False, 0, 0),       # cross-attn, irregular sizes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_vs_jnp(sq, skv, hq, hkv, dh, causal, window, prefix,
                             dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, dh), dtype)
    k = jax.random.normal(ks[1], (2, skv, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (2, skv, hkv, dh), dtype)
    out_p = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                   prefix_len=prefix, interpret=True)
    out_j = _flash_jnp(q, k, v, jnp.arange(sq), jnp.arange(skv),
                       causal=causal, window=window, prefix_len=prefix,
                       q_chunk=64, kv_chunk=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_j, np.float32),
                               atol=tol, rtol=tol)
